//! Validate-once evaluation sessions.
//!
//! The paper's usage model (§VII) evaluates thousands of mappings per
//! (workload, architecture) pair: every search iteration, case-study sweep,
//! and Pareto enumeration re-walks the same fusion set under a different
//! [`InterLayerMapping`]. An [`Evaluator`] validates the fusion set and
//! architecture once, precomputes the per-layer intra-layer defaults,
//! spatial fanouts, and action-count constants, and then evaluates mappings
//! with only the cheap per-call mapping validation on the hot path — via
//! the steady-state fast path by default (see the `engine` module docs), or
//! the exhaustive reference walk through [`Evaluator::evaluate_reference`].

use super::engine::{evaluate_prevalidated, resolve_intra, EvalScratch, SessionCache};
use super::metrics::Metrics;
use crate::analysis::{self, ObjectiveFloors};
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::FusionSet;
use crate::mapping::{InterLayerMapping, IntraLayerMapping};
use std::sync::Mutex;

/// Per-schedule-level diagnostic of [`Evaluator::explain`]: whether the
/// static prover certified the level's steady-state jump, and why not.
#[derive(Debug, Clone)]
pub struct LevelExplain {
    /// Schedule level index (0 = outermost).
    pub level: usize,
    /// Partitioned rank name (of the sink layer).
    pub dim: String,
    /// Tile size at this level.
    pub tile: i64,
    /// Child count of this level (`ceil(extent / tile)`).
    pub children: i64,
    /// Whether the static prover certified this level's jump.
    pub proven: bool,
    /// Refusal reason when not proven (empty when proven). Unproven levels
    /// still jump when the empirical two-child certification succeeds.
    pub reason: String,
}

/// The result of [`Evaluator::explain`]: which evaluation paths fired for
/// one mapping, and why the tiers that did not fire were skipped.
#[derive(Debug, Clone)]
pub struct EvalExplain {
    /// Whether the tier-1 symbolic box walk covered the whole evaluation.
    pub symbolic: bool,
    /// Why the symbolic walk did not fire (`None` when it did): the first
    /// failing static gate, or the runtime box-closure refusal.
    pub skip_reason: Option<String>,
    /// Per-schedule-level prover verdicts.
    pub levels: Vec<LevelExplain>,
    /// The evaluation result (its [`Metrics::path`] holds the fire
    /// counters).
    pub metrics: Metrics,
}

/// A pool of reusable [`EvalScratch`] buffers. Each `evaluate` call checks
/// one out for the duration of its walk, so concurrent batch evaluation
/// keeps one warm scratch per worker instead of allocating per iteration.
#[derive(Debug, Default)]
struct ScratchPool {
    pool: Mutex<Vec<EvalScratch>>,
}

impl ScratchPool {
    fn take(&self) -> EvalScratch {
        self.pool
            .lock()
            .map(|mut p| p.pop().unwrap_or_default())
            .unwrap_or_default()
    }

    fn put(&self, scratch: EvalScratch) {
        if let Ok(mut p) = self.pool.lock() {
            if p.len() < 64 {
                p.push(scratch);
            }
        }
    }
}

/// A validate-once evaluation session for one (fusion set, architecture)
/// pair. Cheap to share across threads (`&Evaluator` is `Sync`): the
/// searches and the [`Coordinator`] fan one session out over a worker pool.
#[derive(Debug)]
pub struct Evaluator {
    fs: FusionSet,
    arch: Arch,
    intra: Vec<IntraLayerMapping>,
    cache: SessionCache,
    scratch: ScratchPool,
}

impl Clone for Evaluator {
    fn clone(&self) -> Self {
        Evaluator {
            fs: self.fs.clone(),
            arch: self.arch.clone(),
            intra: self.intra.clone(),
            cache: self.cache.clone(),
            scratch: ScratchPool::default(),
        }
    }
}

impl Evaluator {
    /// Validate both specs once and derive the default intra-layer mapping
    /// for every layer. Errors on structurally invalid specs.
    pub fn new(fs: &FusionSet, arch: &Arch) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, None)?;
        let cache = SessionCache::build(fs, arch, &intra);
        Ok(Evaluator {
            fs: fs.clone(),
            arch: arch.clone(),
            intra,
            cache,
            scratch: ScratchPool::default(),
        })
    }

    /// Like [`Evaluator::new`], but with explicit per-layer intra-layer
    /// mappings (validated here) instead of the derived defaults.
    pub fn with_intra(
        fs: &FusionSet,
        arch: &Arch,
        intra: &[IntraLayerMapping],
    ) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, Some(intra))?;
        let cache = SessionCache::build(fs, arch, &intra);
        Ok(Evaluator {
            fs: fs.clone(),
            arch: arch.clone(),
            intra,
            cache,
            scratch: ScratchPool::default(),
        })
    }

    /// The session's fusion set.
    pub fn fusion_set(&self) -> &FusionSet {
        &self.fs
    }

    /// The session's architecture.
    pub fn arch(&self) -> &Arch {
        &self.arch
    }

    /// The resolved per-layer intra-layer mappings.
    pub fn intra(&self) -> &[IntraLayerMapping] {
        &self.intra
    }

    /// Closed-form lower bound on [`Metrics::occupancy_peak`] for `mapping`,
    /// in elements — no walk (see [`analysis::capacity_lower_bound`]).
    /// Errors on mappings this session would reject at evaluation. Reuses
    /// the session's cached surjectivity verdict instead of re-deriving it
    /// per call.
    pub fn capacity_lower_bound(&self, mapping: &InterLayerMapping) -> Result<i64, String> {
        mapping.validate(&self.fs)?;
        Ok(analysis::capacity_lower_bound_given(
            &self.fs,
            mapping,
            self.cache.statics.surjective,
        ))
    }

    /// The session's mapping-independent metric floors (see
    /// [`analysis::objective_floors`]); built once at session construction.
    pub fn floors(&self) -> &ObjectiveFloors {
        &self.cache.floors
    }

    /// Evaluate one inter-layer mapping. Identical results to the free
    /// [`super::evaluate`], minus its per-call spec re-validation; uses the
    /// steady-state fast path whenever the mapping qualifies, falling back
    /// to the exhaustive walk otherwise (bit-identical either way).
    pub fn evaluate(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, false, false)
    }

    /// Evaluate with the exhaustive reference walk (all fast paths
    /// disabled). This is the verification oracle: it walks every
    /// inter-layer iteration and must agree with [`Evaluator::evaluate`]
    /// bit-for-bit (modulo the diagnostic [`Metrics::path`] counters).
    pub fn evaluate_reference(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, true, false)
    }

    /// Evaluate with the tier-1 symbolic box walk disabled but the tier-2
    /// steady-state jumps kept — the middle rung of the hierarchy, for
    /// verification and benchmarking. Bit-identical to the other paths
    /// (modulo [`Metrics::path`]).
    pub fn evaluate_no_symbolic(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, false, true)
    }

    fn run(
        &self,
        mapping: &InterLayerMapping,
        force_reference: bool,
        no_symbolic: bool,
    ) -> Result<Metrics, String> {
        let mut scratch = self.scratch.take();
        let result = evaluate_prevalidated(
            &self.fs,
            &self.arch,
            mapping,
            &self.cache,
            &mut scratch,
            force_reference,
            no_symbolic,
        );
        self.scratch.put(scratch);
        result
    }

    /// Evaluate `mapping` and report *which* evaluation paths fired and why
    /// the others did not — the diagnostic behind `analyze --explain`.
    pub fn explain(&self, mapping: &InterLayerMapping) -> Result<EvalExplain, String> {
        let metrics = self.evaluate(mapping)?;
        let counts = mapping.level_counts(&self.fs);
        let verbose =
            analysis::prove_levels_verbose(&self.fs, &self.cache.statics, mapping, &counts);
        let sink = self.fs.last();
        let levels = mapping
            .partitions
            .iter()
            .zip(&verbose)
            .enumerate()
            .map(|(l, (p, r))| LevelExplain {
                level: l,
                dim: sink.rank_names[p.dim].clone(),
                tile: p.tile,
                children: counts[l],
                proven: r.is_ok(),
                reason: match r {
                    Ok(_) => String::new(),
                    Err(e) => e.describe(&self.fs),
                },
            })
            .collect();
        let skip_reason = if metrics.path.symbolic {
            None
        } else if !self.cache.statics.surjective {
            Some(
                "session is not surjective (producer images do not cover their tensors)"
                    .to_string(),
            )
        } else if !self.fs.is_chain() {
            Some(
                "fusion set is not a chain (some tensor has multiple consumers)".to_string(),
            )
        } else if !mapping
            .partitions
            .iter()
            .all(|p| self.cache.statics.out_dims.contains(&p.dim))
        {
            Some(
                "a partitioned rank is absent from the sink output access \
                 (reduction-rank partitioning)"
                    .to_string(),
            )
        } else {
            Some(
                "box-closure refusal at runtime: an availability or fresh set \
                 left single-box form mid-walk"
                    .to_string(),
            )
        };
        Ok(EvalExplain {
            symbolic: metrics.path.symbolic,
            skip_reason,
            levels,
            metrics,
        })
    }

    /// Evaluate a batch on a worker pool; results preserve input order, and
    /// individual failures are reported per slot.
    pub fn evaluate_batch(
        &self,
        mappings: &[InterLayerMapping],
        pool: &Coordinator,
    ) -> Vec<Result<Metrics, String>> {
        pool.run(mappings.len(), |i| self.evaluate(&mappings[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::mapping::{Parallelism, Partition};
    use crate::model::{evaluate, EvalOptions};

    #[test]
    fn session_matches_free_function() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        for tile in [1, 3, 4, 12] {
            let mapping = InterLayerMapping::tiled(
                vec![Partition { dim: p2, tile }],
                Parallelism::Sequential,
            );
            let a = ev.evaluate(&mapping).unwrap();
            let b = evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.offchip_reads, b.offchip_reads);
            assert_eq!(a.offchip_writes, b.offchip_writes);
            assert_eq!(a.occupancy_peak, b.occupancy_peak);
            assert_eq!(a.total_ops, b.total_ops);
            assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
        }
    }

    #[test]
    fn explain_reports_symbolic_and_all_tiers_agree() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 2 }],
            Parallelism::Sequential,
        );
        let ex = ev.explain(&mapping).unwrap();
        assert!(ex.symbolic, "symbolic skipped: {:?}", ex.skip_reason);
        assert!(ex.skip_reason.is_none());
        assert_eq!(ex.levels.len(), 1);
        assert_eq!(ex.levels[0].dim, "P2");
        assert_eq!(ex.levels[0].children, 7);

        let mut a = ev.evaluate(&mapping).unwrap();
        let mut b = ev.evaluate_no_symbolic(&mapping).unwrap();
        let mut c = ev.evaluate_reference(&mapping).unwrap();
        assert!(a.path.symbolic);
        assert!(!b.path.symbolic && !c.path.symbolic);
        // The reference walk never jumps; the middle tier may.
        assert_eq!(c.path.proven_jumps + c.path.certified_jumps, 0);
        a.path = Default::default();
        b.path = Default::default();
        c.path = Default::default();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(format!("{a:?}"), format!("{c:?}"));
    }

    #[test]
    fn invalid_specs_rejected_at_construction() {
        let fs = workloads::conv_conv(14, 8);
        let mut bad_arch = Arch::generic(256);
        bad_arch.compute.macs = 0;
        assert!(Evaluator::new(&fs, &bad_arch).is_err());
    }

    #[test]
    fn invalid_mapping_rejected_per_call() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        assert!(ev.evaluate(&bad).is_err());
    }

    #[test]
    fn batch_preserves_order_and_errors() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let good = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        let pool = Coordinator::new(3);
        let out = ev.evaluate_batch(&[good.clone(), bad, good], &pool);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(out[1].is_err());
    }
}
