//! Validate-once evaluation sessions.
//!
//! The paper's usage model (§VII) evaluates thousands of mappings per
//! (workload, architecture) pair: every search iteration, case-study sweep,
//! and Pareto enumeration re-walks the same fusion set under a different
//! [`InterLayerMapping`]. An [`Evaluator`] validates the fusion set and
//! architecture once, precomputes the per-layer intra-layer defaults,
//! spatial fanouts, and action-count constants, and then evaluates mappings
//! with only the cheap per-call mapping validation on the hot path — via
//! the steady-state fast path by default (see the `engine` module docs), or
//! the exhaustive reference walk through [`Evaluator::evaluate_reference`].

use super::engine::{evaluate_prevalidated, resolve_intra, EvalScratch, SessionCache};
use super::metrics::Metrics;
use crate::analysis::{self, ObjectiveFloors};
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::{FusionSet, TensorId};
use crate::mapping::{InterLayerMapping, IntraLayerMapping};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashSet;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-schedule-level diagnostic of [`Evaluator::explain`]: whether the
/// static prover certified the level's steady-state jump, and why not.
#[derive(Debug, Clone)]
pub struct LevelExplain {
    /// Schedule level index (0 = outermost).
    pub level: usize,
    /// Partitioned rank name (of the sink layer).
    pub dim: String,
    /// Tile size at this level.
    pub tile: i64,
    /// Child count of this level (`ceil(extent / tile)`).
    pub children: i64,
    /// Whether the static prover certified this level's jump.
    pub proven: bool,
    /// Refusal reason when not proven (empty when proven). Unproven levels
    /// still jump when the empirical two-child certification succeeds.
    pub reason: String,
    /// Widest availability box union observed at this level's child
    /// boundaries during the symbolic walk (0 when the symbolic tier did
    /// not cover the evaluation; 2 marks the multibox path of row+column
    /// tilings).
    pub union_width: i64,
}

/// The result of [`Evaluator::explain`]: which evaluation paths fired for
/// one mapping, and why the tiers that did not fire were skipped.
#[derive(Debug, Clone)]
pub struct EvalExplain {
    /// Whether the tier-1 symbolic box walk covered the whole evaluation.
    pub symbolic: bool,
    /// Why the symbolic walk did not fire (`None` when it did): the first
    /// failing static gate, or the runtime box-closure refusal.
    pub skip_reason: Option<String>,
    /// Per-schedule-level prover verdicts.
    pub levels: Vec<LevelExplain>,
    /// The evaluation result (its [`Metrics::path`] holds the fire
    /// counters).
    pub metrics: Metrics,
}

/// A pool of reusable [`EvalScratch`] buffers. Each `evaluate` call checks
/// one out for the duration of its walk, so concurrent batch evaluation
/// keeps one warm scratch per worker instead of allocating per iteration.
#[derive(Debug, Default)]
struct ScratchPool {
    pool: Mutex<Vec<EvalScratch>>,
}

impl ScratchPool {
    fn take(&self) -> EvalScratch {
        self.pool
            .lock()
            .map(|mut p| p.pop().unwrap_or_default())
            .unwrap_or_default()
    }

    fn put(&self, scratch: EvalScratch) {
        if let Ok(mut p) = self.pool.lock() {
            if p.len() < 64 {
                p.push(scratch);
            }
        }
    }
}

/// A validate-once evaluation session for one (fusion set, architecture)
/// pair. Cheap to share across threads (`&Evaluator` is `Sync`): the
/// searches and the [`Coordinator`] fan one session out over a worker pool.
#[derive(Debug)]
pub struct Evaluator {
    fs: FusionSet,
    arch: Arch,
    intra: Vec<IntraLayerMapping>,
    cache: SessionCache,
    scratch: ScratchPool,
    /// Signatures of mappings whose symbolic attempt refused at runtime in
    /// this session. A refusal pays a full re-`prepare` plus the region
    /// walk, so re-evaluations of a memoized mapping (annealing and genetic
    /// searches revisit points constantly) skip the symbolic attempt
    /// outright. The signature is the full canonical mapping shape —
    /// partitions, resolved retention, parallelism — so only mappings whose
    /// walk is identical to a known-refusing one are skipped, keeping tier
    /// attribution (and the searches' `symbolic_evals` counters)
    /// deterministic.
    refused_shapes: Mutex<HashSet<u64>>,
    /// Symbolic attempts skipped via `refused_shapes`.
    memo_hits: AtomicUsize,
}

impl Clone for Evaluator {
    fn clone(&self) -> Self {
        Evaluator {
            fs: self.fs.clone(),
            arch: self.arch.clone(),
            intra: self.intra.clone(),
            cache: self.cache.clone(),
            scratch: ScratchPool::default(),
            refused_shapes: Mutex::new(HashSet::new()),
            memo_hits: AtomicUsize::new(0),
        }
    }
}

impl Evaluator {
    /// Validate both specs once and derive the default intra-layer mapping
    /// for every layer. Errors on structurally invalid specs.
    pub fn new(fs: &FusionSet, arch: &Arch) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, None)?;
        let cache = SessionCache::build(fs, arch, &intra);
        Ok(Evaluator {
            fs: fs.clone(),
            arch: arch.clone(),
            intra,
            cache,
            scratch: ScratchPool::default(),
            refused_shapes: Mutex::new(HashSet::new()),
            memo_hits: AtomicUsize::new(0),
        })
    }

    /// Like [`Evaluator::new`], but with explicit per-layer intra-layer
    /// mappings (validated here) instead of the derived defaults.
    pub fn with_intra(
        fs: &FusionSet,
        arch: &Arch,
        intra: &[IntraLayerMapping],
    ) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, Some(intra))?;
        let cache = SessionCache::build(fs, arch, &intra);
        Ok(Evaluator {
            fs: fs.clone(),
            arch: arch.clone(),
            intra,
            cache,
            scratch: ScratchPool::default(),
            refused_shapes: Mutex::new(HashSet::new()),
            memo_hits: AtomicUsize::new(0),
        })
    }

    /// The session's fusion set.
    pub fn fusion_set(&self) -> &FusionSet {
        &self.fs
    }

    /// The session's architecture.
    pub fn arch(&self) -> &Arch {
        &self.arch
    }

    /// The resolved per-layer intra-layer mappings.
    pub fn intra(&self) -> &[IntraLayerMapping] {
        &self.intra
    }

    /// Closed-form lower bound on [`Metrics::occupancy_peak`] for `mapping`,
    /// in elements — no walk (see [`analysis::capacity_lower_bound`]).
    /// Errors on mappings this session would reject at evaluation. Reuses
    /// the session's cached surjectivity verdict instead of re-deriving it
    /// per call.
    pub fn capacity_lower_bound(&self, mapping: &InterLayerMapping) -> Result<i64, String> {
        mapping.validate(&self.fs)?;
        Ok(analysis::capacity_lower_bound_given(
            &self.fs,
            mapping,
            self.cache.statics.surjective,
        ))
    }

    /// The session's mapping-independent metric floors (see
    /// [`analysis::objective_floors`]); built once at session construction.
    pub fn floors(&self) -> &ObjectiveFloors {
        &self.cache.floors
    }

    /// Evaluate one inter-layer mapping. Identical results to the free
    /// [`super::evaluate`], minus its per-call spec re-validation; uses the
    /// steady-state fast path whenever the mapping qualifies, falling back
    /// to the exhaustive walk otherwise (bit-identical either way).
    pub fn evaluate(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, false, false)
    }

    /// Evaluate with the exhaustive reference walk (all fast paths
    /// disabled). This is the verification oracle: it walks every
    /// inter-layer iteration and must agree with [`Evaluator::evaluate`]
    /// bit-for-bit (modulo the diagnostic [`Metrics::path`] counters).
    pub fn evaluate_reference(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, true, false)
    }

    /// Evaluate with the tier-1 symbolic box walk disabled but the tier-2
    /// steady-state jumps kept — the middle rung of the hierarchy, for
    /// verification and benchmarking. Bit-identical to the other paths
    /// (modulo [`Metrics::path`]).
    pub fn evaluate_no_symbolic(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        self.run(mapping, false, true)
    }

    /// Canonical hash of everything about `mapping` the walk depends on.
    /// Retention is resolved per tensor (in tensor order), so mappings that
    /// differ only in `HashMap` iteration order hash identically.
    fn mapping_signature(&self, mapping: &InterLayerMapping) -> u64 {
        let mut h = DefaultHasher::new();
        for p in &mapping.partitions {
            p.dim.hash(&mut h);
            p.tile.hash(&mut h);
        }
        for x in 0..self.fs.tensors.len() {
            mapping.retention_for(TensorId(x)).hash(&mut h);
        }
        (mapping.parallelism == crate::mapping::Parallelism::Pipeline).hash(&mut h);
        h.finish()
    }

    /// Symbolic attempts skipped so far because the mapping's signature was
    /// memoized as refusing (see `refused_shapes`). Monotone within a
    /// session; cloned sessions restart at zero.
    pub fn refusal_memo_hits(&self) -> i64 {
        self.memo_hits.load(Ordering::Relaxed) as i64
    }

    fn run(
        &self,
        mapping: &InterLayerMapping,
        force_reference: bool,
        no_symbolic: bool,
    ) -> Result<Metrics, String> {
        // Refusal memo: a symbolic attempt that bailed mid-walk paid a full
        // re-`prepare` before the region walk; the second time the same
        // mapping shows up (search loops revisit points constantly) the
        // attempt is skipped outright.
        let mut no_symbolic = no_symbolic;
        let mut sig = None;
        if !force_reference && !no_symbolic {
            let s = self.mapping_signature(mapping);
            let known_refusing = self
                .refused_shapes
                .lock()
                .map(|memo| memo.contains(&s))
                .unwrap_or(false);
            if known_refusing {
                self.memo_hits.fetch_add(1, Ordering::Relaxed);
                no_symbolic = true;
            } else {
                sig = Some(s);
            }
        }
        let mut scratch = self.scratch.take();
        let result = evaluate_prevalidated(
            &self.fs,
            &self.arch,
            mapping,
            &self.cache,
            &mut scratch,
            force_reference,
            no_symbolic,
        );
        self.scratch.put(scratch);
        if let (Some(s), Ok(m)) = (sig, &result) {
            if m.path.sym_refused {
                if let Ok(mut memo) = self.refused_shapes.lock() {
                    memo.insert(s);
                }
            }
        }
        result
    }

    /// Evaluate `mapping` and report *which* evaluation paths fired and why
    /// the others did not — the diagnostic behind `analyze --explain`.
    pub fn explain(&self, mapping: &InterLayerMapping) -> Result<EvalExplain, String> {
        let metrics = self.evaluate(mapping)?;
        let counts = mapping.level_counts(&self.fs);
        let verbose =
            analysis::prove_levels_verbose(&self.fs, &self.cache.statics, mapping, &counts);
        let sink = self.fs.last();
        let levels = mapping
            .partitions
            .iter()
            .zip(&verbose)
            .enumerate()
            .map(|(l, (p, r))| LevelExplain {
                level: l,
                dim: sink.rank_names[p.dim].clone(),
                tile: p.tile,
                children: counts[l],
                proven: r.is_ok(),
                reason: match r {
                    Ok(_) => String::new(),
                    Err(e) => e.describe(&self.fs),
                },
                union_width: metrics
                    .path
                    .level_union_widths
                    .get(l)
                    .copied()
                    .unwrap_or(0),
            })
            .collect();
        let skip_reason = if metrics.path.symbolic {
            None
        } else if !self.cache.statics.surjective {
            Some(
                "session is not surjective (producer images do not cover their tensors)"
                    .to_string(),
            )
        } else if !self.fs.is_chain() {
            Some(
                "fusion set is not a chain (some tensor has multiple consumers)".to_string(),
            )
        } else if !mapping
            .partitions
            .iter()
            .all(|p| self.cache.statics.out_dims.contains(&p.dim))
        {
            Some(
                "a partitioned rank is absent from the sink output access \
                 (reduction-rank partitioning)"
                    .to_string(),
            )
        } else if metrics.path.sym_refused {
            Some(
                "union-calculus refusal at runtime: an availability or fresh \
                 set exceeded the bounded box-union width mid-walk"
                    .to_string(),
            )
        } else {
            Some(
                "a previous evaluation of this mapping refused mid-walk \
                 (memoized; the symbolic attempt was skipped)"
                    .to_string(),
            )
        };
        Ok(EvalExplain {
            symbolic: metrics.path.symbolic,
            skip_reason,
            levels,
            metrics,
        })
    }

    /// Evaluate a batch on a worker pool; results preserve input order, and
    /// individual failures are reported per slot.
    pub fn evaluate_batch(
        &self,
        mappings: &[InterLayerMapping],
        pool: &Coordinator,
    ) -> Vec<Result<Metrics, String>> {
        pool.run(mappings.len(), |i| self.evaluate(&mappings[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::mapping::{Parallelism, Partition};
    use crate::model::{evaluate, EvalOptions};

    #[test]
    fn session_matches_free_function() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        for tile in [1, 3, 4, 12] {
            let mapping = InterLayerMapping::tiled(
                vec![Partition { dim: p2, tile }],
                Parallelism::Sequential,
            );
            let a = ev.evaluate(&mapping).unwrap();
            let b = evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.offchip_reads, b.offchip_reads);
            assert_eq!(a.offchip_writes, b.offchip_writes);
            assert_eq!(a.occupancy_peak, b.occupancy_peak);
            assert_eq!(a.total_ops, b.total_ops);
            assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
        }
    }

    #[test]
    fn explain_reports_symbolic_and_all_tiers_agree() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let mapping = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 2 }],
            Parallelism::Sequential,
        );
        let ex = ev.explain(&mapping).unwrap();
        assert!(ex.symbolic, "symbolic skipped: {:?}", ex.skip_reason);
        assert!(ex.skip_reason.is_none());
        assert_eq!(ex.levels.len(), 1);
        assert_eq!(ex.levels[0].dim, "P2");
        assert_eq!(ex.levels[0].children, 7);

        let mut a = ev.evaluate(&mapping).unwrap();
        let mut b = ev.evaluate_no_symbolic(&mapping).unwrap();
        let mut c = ev.evaluate_reference(&mapping).unwrap();
        assert!(a.path.symbolic);
        assert!(!b.path.symbolic && !c.path.symbolic);
        // The reference walk never jumps; the middle tier may.
        assert_eq!(c.path.proven_jumps + c.path.certified_jumps, 0);
        a.path = Default::default();
        b.path = Default::default();
        c.path = Default::default();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(format!("{a:?}"), format!("{c:?}"));
    }

    #[test]
    fn refusal_memo_skips_repeat_attempts() {
        use crate::einsum::FusionSetBuilder;
        // Two chained batched convs under a B,P,Q partition with retention 0:
        // at the wrap leaf (b=1, p=1, q=0) the first layer's input fmap
        // availability is a batch slab plus a row band plus a fresh corner —
        // three disjoint boxes — so the width-2 union calculus refuses.
        let fs = FusionSetBuilder::new("memo_refuse", &[3, 2, 8, 8])
            .conv2d_batched(2, 3, 3, 1)
            .conv2d_batched(2, 3, 3, 1)
            .build();
        let arch = Arch::generic(4096);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let last = fs.last();
        let mapping = InterLayerMapping::tiled(
            ["B2", "P2", "Q2"]
                .iter()
                .map(|n| Partition { dim: last.rank_index(n).unwrap(), tile: 1 })
                .collect(),
            Parallelism::Sequential,
        )
        .with_uniform_retention(0);

        let mut a = ev.evaluate(&mapping).unwrap();
        assert!(a.path.sym_refused, "expected a runtime refusal; path={:?}", a.path);
        assert!(!a.path.symbolic);
        assert_eq!(ev.refusal_memo_hits(), 0);

        let mut b = ev.evaluate(&mapping).unwrap();
        assert!(!b.path.symbolic);
        assert!(!b.path.sym_refused, "memoized run must skip the attempt");
        assert_eq!(ev.refusal_memo_hits(), 1);

        // The memoized skip is bit-identical to the refused-then-bailed run,
        // and both agree with the reference walk.
        let mut c = ev.evaluate_reference(&mapping).unwrap();
        a.path = Default::default();
        b.path = Default::default();
        c.path = Default::default();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(format!("{a:?}"), format!("{c:?}"));
    }

    #[test]
    fn invalid_specs_rejected_at_construction() {
        let fs = workloads::conv_conv(14, 8);
        let mut bad_arch = Arch::generic(256);
        bad_arch.compute.macs = 0;
        assert!(Evaluator::new(&fs, &bad_arch).is_err());
    }

    #[test]
    fn invalid_mapping_rejected_per_call() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        assert!(ev.evaluate(&bad).is_err());
    }

    #[test]
    fn batch_preserves_order_and_errors() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let good = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        let pool = Coordinator::new(3);
        let out = ev.evaluate_batch(&[good.clone(), bad, good], &pool);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(out[1].is_err());
    }
}
