//! Validate-once evaluation sessions.
//!
//! The paper's usage model (§VII) evaluates thousands of mappings per
//! (workload, architecture) pair: every search iteration, case-study sweep,
//! and Pareto enumeration re-walks the same fusion set under a different
//! [`InterLayerMapping`]. An [`Evaluator`] validates the fusion set and
//! architecture once, precomputes the per-layer intra-layer defaults and
//! spatial fanouts, and then evaluates mappings with only the cheap per-call
//! mapping validation on the hot path.

use super::engine::{evaluate_prevalidated, fanouts, resolve_intra};
use super::metrics::Metrics;
use crate::arch::Arch;
use crate::coordinator::Coordinator;
use crate::einsum::FusionSet;
use crate::mapping::{InterLayerMapping, IntraLayerMapping};

/// A validate-once evaluation session for one (fusion set, architecture)
/// pair. Cheap to share across threads (`&Evaluator` is `Sync`): the
/// searches and the [`Coordinator`] fan one session out over a worker pool.
#[derive(Debug, Clone)]
pub struct Evaluator {
    fs: FusionSet,
    arch: Arch,
    intra: Vec<IntraLayerMapping>,
    fanout: Vec<i64>,
}

impl Evaluator {
    /// Validate both specs once and derive the default intra-layer mapping
    /// for every layer. Errors on structurally invalid specs.
    pub fn new(fs: &FusionSet, arch: &Arch) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, None)?;
        let fanout = fanouts(&intra, arch);
        Ok(Evaluator { fs: fs.clone(), arch: arch.clone(), intra, fanout })
    }

    /// Like [`Evaluator::new`], but with explicit per-layer intra-layer
    /// mappings (validated here) instead of the derived defaults.
    pub fn with_intra(
        fs: &FusionSet,
        arch: &Arch,
        intra: &[IntraLayerMapping],
    ) -> Result<Evaluator, String> {
        fs.validate()?;
        arch.validate()?;
        let intra = resolve_intra(fs, arch, Some(intra))?;
        let fanout = fanouts(&intra, arch);
        Ok(Evaluator { fs: fs.clone(), arch: arch.clone(), intra, fanout })
    }

    /// The session's fusion set.
    pub fn fusion_set(&self) -> &FusionSet {
        &self.fs
    }

    /// The session's architecture.
    pub fn arch(&self) -> &Arch {
        &self.arch
    }

    /// The resolved per-layer intra-layer mappings.
    pub fn intra(&self) -> &[IntraLayerMapping] {
        &self.intra
    }

    /// Evaluate one inter-layer mapping. Identical results to the free
    /// [`super::evaluate`], minus its per-call spec re-validation.
    pub fn evaluate(&self, mapping: &InterLayerMapping) -> Result<Metrics, String> {
        evaluate_prevalidated(&self.fs, &self.arch, mapping, &self.intra, &self.fanout)
    }

    /// Evaluate a batch on a worker pool; results preserve input order, and
    /// individual failures are reported per slot.
    pub fn evaluate_batch(
        &self,
        mappings: &[InterLayerMapping],
        pool: &Coordinator,
    ) -> Vec<Result<Metrics, String>> {
        pool.run(mappings.len(), |i| self.evaluate(&mappings[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::mapping::{Parallelism, Partition};
    use crate::model::{evaluate, EvalOptions};

    #[test]
    fn session_matches_free_function() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        for tile in [1, 3, 4, 12] {
            let mapping = InterLayerMapping::tiled(
                vec![Partition { dim: p2, tile }],
                Parallelism::Sequential,
            );
            let a = ev.evaluate(&mapping).unwrap();
            let b = evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.offchip_reads, b.offchip_reads);
            assert_eq!(a.offchip_writes, b.offchip_writes);
            assert_eq!(a.occupancy_peak, b.occupancy_peak);
            assert_eq!(a.total_ops, b.total_ops);
            assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
        }
    }

    #[test]
    fn invalid_specs_rejected_at_construction() {
        let fs = workloads::conv_conv(14, 8);
        let mut bad_arch = Arch::generic(256);
        bad_arch.compute.macs = 0;
        assert!(Evaluator::new(&fs, &bad_arch).is_err());
    }

    #[test]
    fn invalid_mapping_rejected_per_call() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        assert!(ev.evaluate(&bad).is_err());
    }

    #[test]
    fn batch_preserves_order_and_errors() {
        let fs = workloads::conv_conv(14, 8);
        let arch = Arch::generic(256);
        let ev = Evaluator::new(&fs, &arch).unwrap();
        let p2 = fs.last().rank_index("P2").unwrap();
        let good = InterLayerMapping::tiled(
            vec![Partition { dim: p2, tile: 4 }],
            Parallelism::Sequential,
        );
        let bad = InterLayerMapping::tiled(
            vec![Partition { dim: 999, tile: 2 }],
            Parallelism::Sequential,
        );
        let pool = Coordinator::new(3);
        let out = ev.evaluate_batch(&[good.clone(), bad, good], &pool);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(out[1].is_err());
    }
}
