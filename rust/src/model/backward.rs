//! Backward tile-shape analysis (paper §IV-A, Fig 10).
//!
//! Given an operation window of the *last* (sink) layer, infer the operation
//! and data tiles of every earlier layer through data dependencies: the
//! input data needed by a consumer op region is its image under the input
//! access; the producer ops required to create a data region are its
//! preimage under the producer's (identity) output access, extended fully
//! along the producer's reduction ranks.
//!
//! The fusion set may be any single-sink DAG in topological order (see
//! [`FusionSet::validate`]), not just a chain: an intermediate with several
//! consumers (a residual fan-out) accumulates the union of their needs
//! before its producer — processed after all consumers in the reverse
//! topological sweep — materializes it once. On chains this reduces to the
//! classic layer-by-layer recursion, box for box.

use crate::einsum::FusionSet;
use crate::poly::{IBox, Region};

/// Full (retention-free) needs of a last-layer op window: per-layer operation
/// regions and per-tensor data regions, ignoring any prior availability.
/// These are the paper's *tiles*: what a window touches end to end, used for
/// retained-tile footprints.
#[derive(Debug, Clone, Default)]
pub struct WindowNeeds {
    /// Operation region per layer (read by unit tests and kept for
    /// debuggability; the engine consumes `data`).
    #[allow(dead_code)]
    pub ops: Vec<Region>,
    /// Data region per tensor (index = TensorId.0).
    pub data: Vec<Region>,
}

/// Propagate full needs backward from a last-layer op window.
pub fn window_needs(fs: &FusionSet, last_ops: &IBox) -> WindowNeeds {
    let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();
    let mut out = WindowNeeds::default();
    let mut tmp = IBox::empty(0);
    window_needs_into(fs, last_ops, &domains, &mut out, &mut tmp);
    out
}

/// [`window_needs`] into a caller-provided [`WindowNeeds`] (reuses every
/// region's storage). `domains` caches `einsums[t].domain()` per layer;
/// `tmp` is box scratch.
pub(crate) fn window_needs_into(
    fs: &FusionSet,
    last_ops: &IBox,
    domains: &[IBox],
    out: &mut WindowNeeds,
    tmp: &mut IBox,
) {
    let n = fs.num_layers();
    out.ops.resize_with(n, || Region::empty(0));
    out.data.resize_with(fs.tensors.len(), || Region::empty(0));
    for (t, e) in fs.einsums.iter().enumerate() {
        out.ops[t].reset(e.ndim());
    }
    for (x, tn) in fs.tensors.iter().enumerate() {
        out.data[x].reset(tn.ndim());
    }

    let WindowNeeds { ops, data } = out;
    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        // Op region: the mapped window at the sink; upstream, the preimage
        // of whatever this layer's consumers — all later in the topological
        // order, hence already processed — asked its output tensor to cover.
        if t == n - 1 {
            ops[t].assign_box(last_ops);
        } else {
            for b in data[e.output.tensor.0].boxes() {
                e.output.map.preimage_identity_box_into(b, &domains[t], tmp);
                ops[t].union_box(tmp);
            }
        }
        // Output data of this layer's op region.
        for b in ops[t].boxes() {
            e.output.map.image_box_into(b, tmp);
            data[e.output.tensor.0].union_box(tmp);
        }
        // Input needs.
        for acc in &e.inputs {
            for b in ops[t].boxes() {
                acc.map.image_box_into(b, tmp);
                data[acc.tensor.0].union_box(tmp);
            }
        }
    }
}

/// Per-iteration backward pass *with* availability subtraction: computes the
/// fresh (to be fetched or recomputed) data per tensor and the actual op
/// regions per layer, updating `avail` in place.
///
/// `avail[x]` must already reflect retention-window invalidation for this
/// iteration (see the engine's retention step).
#[derive(Debug, Clone)]
pub struct IterResult {
    /// Actual ops executed per layer this iteration.
    pub ops: Vec<Region>,
    /// Freshly fetched (off-chip-backed) or produced (intermediate / output)
    /// volume per tensor.
    pub fresh: Vec<i64>,
}

/// Reusable storage for [`iter_backward_into`]: the per-layer op regions,
/// per-tensor fresh volumes, and the region/box temporaries of one backward
/// pass. One instance serves every iteration of a walk, so the hot path
/// performs no heap allocation (beyond amortized growth).
#[derive(Debug, Clone, Default)]
pub(crate) struct BackwardScratch {
    /// Actual ops executed per layer this iteration.
    pub ops: Vec<Region>,
    /// Fresh volume per tensor this iteration.
    pub fresh: Vec<i64>,
    /// Per-tensor fresh regions consumers have requested but whose producer
    /// has not been reached yet (union across sibling consumers, so shared
    /// skip data is produced and counted once).
    pending: Vec<Region>,
    /// Producing layer per tensor (`usize::MAX` = off-chip source).
    producer: Vec<usize>,
    need: Region,
    fr: Region,
    tmpb: IBox,
}

/// One backward pass from the last layer's operation box `last_ops`:
/// computes the fresh data every tensor needs beyond what `avail` already
/// holds, unions it into `avail`, and returns per-layer operation and
/// fresh-element counts.
pub fn iter_backward(fs: &FusionSet, last_ops: &IBox, avail: &mut [Region]) -> IterResult {
    let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();
    let mut sc = BackwardScratch::default();
    iter_backward_into(fs, last_ops, &domains, avail, &mut sc);
    IterResult { ops: sc.ops, fresh: sc.fresh }
}

/// [`iter_backward`] writing into reusable scratch. `domains` caches
/// `einsums[t].domain()` per layer.
pub(crate) fn iter_backward_into(
    fs: &FusionSet,
    last_ops: &IBox,
    domains: &[IBox],
    avail: &mut [Region],
    sc: &mut BackwardScratch,
) {
    let n = fs.num_layers();
    sc.ops.resize_with(n, || Region::empty(0));
    for (t, e) in fs.einsums.iter().enumerate() {
        sc.ops[t].reset(e.ndim());
    }
    sc.fresh.clear();
    sc.fresh.resize(fs.tensors.len(), 0);
    sc.pending.resize_with(fs.tensors.len(), || Region::empty(0));
    for (x, tn) in fs.tensors.iter().enumerate() {
        sc.pending[x].reset(tn.ndim());
    }
    sc.producer.clear();
    sc.producer.resize(fs.tensors.len(), usize::MAX);
    for (t, e) in fs.einsums.iter().enumerate() {
        sc.producer[e.output.tensor.0] = t;
    }

    sc.ops[n - 1].assign_box(last_ops);
    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        if t < n - 1 {
            // Ops = preimage of the fresh output this layer's consumers (all
            // processed already) requested via `pending`. The preimage of
            // that region images back to exactly itself under the identity
            // output access, so the output pass below counts each produced
            // element once even with several consumers.
            for b in sc.pending[e.output.tensor.0].boxes() {
                e.output.map.preimage_identity_box_into(b, &domains[t], &mut sc.tmpb);
                sc.ops[t].union_box(&sc.tmpb);
            }
        }
        if sc.ops[t].is_empty() {
            continue;
        }
        // Freshly produced output data (for intermediates this is what the
        // consumer-driven recursion asked this layer to produce; for the
        // last layer it is the mapped tile's output).
        let out = e.output.tensor;
        sc.need.reset(fs.tensors[out.0].ndim());
        for b in sc.ops[t].boxes() {
            e.output.map.image_box_into(b, &mut sc.tmpb);
            sc.need.union_box(&sc.tmpb);
        }
        sc.fr.clone_from(&sc.need);
        sc.fr.subtract_assign(&avail[out.0]);
        sc.fresh[out.0] += sc.fr.volume();
        avail[out.0].union(&sc.fr);

        // Input needs: fresh parts must be fetched (weights / input fmap) or
        // produced by the upstream producer layer (intermediates).
        for acc in &e.inputs {
            let x = acc.tensor;
            sc.need.reset(fs.tensors[x.0].ndim());
            for b in sc.ops[t].boxes() {
                acc.map.image_box_into(b, &mut sc.tmpb);
                sc.need.union_box(&sc.tmpb);
            }
            sc.fr.clone_from(&sc.need);
            sc.fr.subtract_assign(&avail[x.0]);
            let p = sc.producer[x.0];
            if p != usize::MAX {
                debug_assert!(p < t, "fusion set is not in topological order");
                // Produced inside the set: defer to the producer's own
                // output pass. Subtract what sibling consumers already
                // requested this iteration so shared data is produced once.
                if !sc.pending[x.0].is_empty() {
                    sc.fr.subtract_assign(&sc.pending[x.0]);
                }
                sc.pending[x.0].union(&sc.fr);
            } else {
                sc.fresh[x.0] += sc.fr.volume();
                avail[x.0].union(&sc.fr);
            }
        }
    }
    // Keep region representations tight for long walks.
    for a in avail.iter_mut() {
        if a.complexity() > 16 {
            a.coalesce();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::workloads;
    use crate::poly::Interval;

    #[test]
    fn full_window_needs_cover_everything() {
        let fs = workloads::conv_conv(14, 4);
        let needs = window_needs(&fs, &fs.last().domain());
        // Processing the whole last layer needs every tensor entirely.
        for (i, t) in fs.tensors.iter().enumerate() {
            assert!(
                needs.data[i].set_eq(&t.full_region()),
                "tensor {} needs {} != full",
                t.name,
                needs.data[i]
            );
        }
        // And the full op space of both layers.
        for (t, e) in fs.einsums.iter().enumerate() {
            assert_eq!(needs.ops[t].volume(), e.total_ops());
        }
    }

    #[test]
    fn row_window_needs_have_halo() {
        let fs = workloads::conv_conv(14, 4); // P2=12, 3x3 convs
        let p2 = fs.last().rank_index("P2").unwrap();
        let mut win = fs.last().domain();
        win.dims[p2] = Interval::new(0, 4); // first 4 output rows
        let needs = window_needs(&fs, &win);
        // Fmap2 rows needed: p2 + r2 -> [0, 6) (halo 2).
        let fmap2 = crate::einsum::TensorId(2);
        assert_eq!(fs.tensor(fmap2).name, "Fmap2");
        let bb = needs.data[fmap2.0].bounding_box();
        assert_eq!(bb.dims[1], Interval::new(0, 6));
        // Fmap1 rows needed: [0, 8) (two layers of halo).
        let bb1 = needs.data[0].bounding_box();
        assert_eq!(bb1.dims[1], Interval::new(0, 8));
        // Conv1 ops: produce 6 rows of Fmap2.
        assert_eq!(
            needs.ops[0].volume(),
            4 * 6 * 14 * 4 * 3 * 3 // M1 * P1tile * Q1 * C1 * R1 * S1
        );
    }

    #[test]
    fn iter_backward_subtracts_availability() {
        let fs = workloads::conv_conv(14, 4);
        let p2 = fs.last().rank_index("P2").unwrap();
        let mut avail: Vec<Region> =
            fs.tensors.iter().map(|t| Region::empty(t.ndim())).collect();

        // Iteration 0: rows [0,4).
        let mut w0 = fs.last().domain();
        w0.dims[p2] = Interval::new(0, 4);
        let r0 = iter_backward(&fs, &w0, &mut avail);
        let fmap2 = 2usize;
        assert_eq!(r0.fresh[fmap2], 4 * 6 * 14); // 6 rows with halo

        // Iteration 1: rows [4,8) — needs Fmap2 rows [4,10); rows [4,6)
        // retained => fresh rows [6,10) = 4 rows.
        let mut w1 = fs.last().domain();
        w1.dims[p2] = Interval::new(4, 8);
        let r1 = iter_backward(&fs, &w1, &mut avail);
        assert_eq!(r1.fresh[fmap2], 4 * 4 * 14);
        // Conv1 ops in iteration 1 produce only the fresh rows.
        assert_eq!(r1.ops[0].volume(), 4 * 4 * 14 * 4 * 9);
    }

    #[test]
    fn iter_backward_recompute_when_not_retained() {
        let fs = workloads::conv_conv(14, 4);
        let p2 = fs.last().rank_index("P2").unwrap();
        let mut avail: Vec<Region> =
            fs.tensors.iter().map(|t| Region::empty(t.ndim())).collect();

        let mut w0 = fs.last().domain();
        w0.dims[p2] = Interval::new(0, 4);
        iter_backward(&fs, &w0, &mut avail);
        // Drop the intermediate entirely (simulates no retention).
        avail[2] = Region::empty(3);
        let mut w1 = fs.last().domain();
        w1.dims[p2] = Interval::new(4, 8);
        let r1 = iter_backward(&fs, &w1, &mut avail);
        // All 6 input rows of Fmap2 are fresh: [4,10) -> recompute overlap.
        assert_eq!(r1.fresh[2], 4 * 6 * 14);
    }
}
