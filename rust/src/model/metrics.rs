//! Final model outputs (paper §IV-C): latency, energy, occupancy, transfers.

use crate::util::table::fmt_count;

/// Energy breakdown by component (pJ).
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    /// Off-chip (DRAM) access energy.
    pub dram_pj: f64,
    /// Global-buffer access energy.
    pub glb_pj: f64,
    /// PE register-file access energy.
    pub rf_pj: f64,
    /// MAC/compute energy.
    pub compute_pj: f64,
    /// Network-on-chip transfer energy.
    pub noc_pj: f64,
}

impl EnergyBreakdown {
    /// Sum over all components (pJ).
    pub fn total_pj(&self) -> f64 {
        self.dram_pj + self.glb_pj + self.rf_pj + self.compute_pj + self.noc_pj
    }
}

/// Which evaluation paths produced a [`Metrics`] — the attribution trail of
/// the three-tier hierarchy (symbolic → proven/certified jumps → walked
/// iterations). Purely diagnostic: two evaluations of the same mapping are
/// bit-identical in every other field regardless of path.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PathCounts {
    /// The whole evaluation ran on the closed-form symbolic box walk.
    pub symbolic: bool,
    /// Steady-state jumps taken on a static (prover-certified) proof.
    pub proven_jumps: i64,
    /// Steady-state jumps taken after empirical two-child certification.
    pub certified_jumps: i64,
    /// Inter-layer iterations actually walked (leaf visits not covered by a
    /// jump); `iterations` minus these is the jump-skipped tile count.
    pub walked_iterations: i64,
    /// Proven jumps taken while some availability union held ≥ 2 boxes —
    /// the closed-form multibox path of row+column output tilings.
    pub multibox_proven_jumps: i64,
    /// Certified jumps taken while some availability union held ≥ 2 boxes.
    pub multibox_certified_jumps: i64,
    /// Widest box union the symbolic walk ever held, across availability
    /// sets and the transient ops/needs/fresh/pending sets of the backward
    /// pass (1 on single-box walks, 2 on multibox walks, 0 when the
    /// symbolic tier did not cover the evaluation).
    pub peak_union_width: i64,
    /// Per schedule level: the widest availability union observed at any
    /// child boundary of that level (empty unless the symbolic tier covered
    /// the evaluation).
    pub level_union_widths: Vec<i64>,
    /// The symbolic tier was attempted but bailed on a union-calculus
    /// refusal mid-walk (the evaluation then reran on the region walk).
    /// `false` when the tier was gated off structurally, skipped via the
    /// refusal memo, or succeeded.
    pub sym_refused: bool,
}

/// Evaluation result for one (fusion set, architecture, mapping) triple.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    // -- latency (cycles) --
    /// Modeled end-to-end latency.
    pub latency_cycles: i64,
    /// Cycles the PE array spends computing.
    pub compute_cycles: i64,
    /// Cycles implied by off-chip bandwidth demand.
    pub memory_cycles: i64,
    /// Sequential-equivalent compute latency (pipeline hides the difference;
    /// paper Fig 12's "sequential minus hidden" analysis).
    pub sequential_compute_cycles: i64,

    // -- energy --
    /// Energy by component (pJ).
    pub energy: EnergyBreakdown,

    // -- transfers (elements / words) --
    /// Elements read from off-chip.
    pub offchip_reads: i64,
    /// Elements written off-chip.
    pub offchip_writes: i64,
    /// Words read from the global buffer.
    pub glb_reads: i64,
    /// Words written to the global buffer.
    pub glb_writes: i64,
    /// NoC traffic in hop-words.
    pub noc_hop_words: f64,
    /// Off-chip traffic per tensor (reads for inputs/weights, writes for the
    /// output fmap; zero for intermediates unless spilled).
    pub per_tensor_offchip: Vec<i64>,

    // -- occupancy (elements) --
    /// Peak simultaneous GLB occupancy across all tensors.
    pub occupancy_peak: i64,
    /// Peak occupancy per tensor (the paper's capacity breakdowns).
    pub per_tensor_occupancy: Vec<i64>,
    /// Whether the peak fits the architecture's GLB capacity.
    pub capacity_ok: bool,

    // -- computation --
    /// Total executed ops (≥ algorithmic due to recomputation).
    pub total_ops: i64,
    /// Executed minus algorithmic ops.
    pub recompute_ops: i64,
    /// Recomputed elements per tensor (intermediates only).
    pub per_tensor_recompute: Vec<i64>,

    /// Number of inter-layer iterations walked.
    pub iterations: i64,

    /// Which evaluation paths fired (diagnostic only — identical mappings
    /// evaluate to identical metrics in every other field on every path).
    pub path: PathCounts,
}

impl Metrics {
    /// Total off-chip traffic in elements.
    pub fn offchip_total(&self) -> i64 {
        self.offchip_reads + self.offchip_writes
    }

    /// Occupancy in bytes for a given word size.
    pub fn occupancy_bytes(&self, word_bytes: i64) -> i64 {
        self.occupancy_peak * word_bytes
    }

    /// Total energy in microjoules.
    pub fn energy_uj(&self) -> f64 {
        self.energy.total_pj() / 1e6
    }

    /// Recompute overhead as a fraction of algorithmic ops.
    pub fn recompute_fraction(&self) -> f64 {
        let alg = self.total_ops - self.recompute_ops;
        if alg == 0 {
            0.0
        } else {
            self.recompute_ops as f64 / alg as f64
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "latency={}cyc (comp={}, mem={}) energy={:.2}uJ offchip={}r+{}w occ={} ops={} (+{} recomp) it={}",
            fmt_count(self.latency_cycles),
            fmt_count(self.compute_cycles),
            fmt_count(self.memory_cycles),
            self.energy_uj(),
            fmt_count(self.offchip_reads),
            fmt_count(self.offchip_writes),
            fmt_count(self.occupancy_peak),
            fmt_count(self.total_ops),
            fmt_count(self.recompute_ops),
            self.iterations,
        )
    }
}
