//! The model evaluation engine: walks the inter-layer schedule
//! algebraically, accumulating all metrics — through a three-tier path
//! hierarchy that makes evaluation cost scale with the number of schedule
//! levels (symbolic), or the number of *distinct* tile shapes (jumps),
//! instead of the total tile count (reference walk).
//!
//! # Tile classification (paper §III-E, imperfect factorization)
//!
//! Each schedule level classifies its iterations into at most three classes:
//!
//! * **first** (`i = 0`) — the cold-start tile: halos have no retained
//!   predecessor, so its fetch/recompute volumes differ from every later
//!   tile;
//! * **steady** (`0 < i < count−1`) — interior tiles: exactly the
//!   translates of one another. Retention windows, backward-pass regions,
//!   fresh volumes, op counts, occupancies, and per-tile latencies repeat
//!   bit-for-bit;
//! * **last** (`i = count−1`) — the ragged tile of an imperfect
//!   factorization (paper §III-E): the window is clipped to the rank
//!   extent, so its shapes differ again. (When the factorization is perfect
//!   the last tile happens to match the steady class, but it is evaluated
//!   explicitly either way.)
//!
//! # The three evaluation tiers
//!
//! **Tier 1 — symbolic box walk** (`sym_level`/`sym_leaf`/`sym_backward`).
//! On surjective chains with every partition on the sink's output ranks,
//! every set the walk manipulates — per-tensor availability, needs, fresh
//! data — stays within a *bounded union of axis-aligned boxes*
//! ([`crate::analysis::symbolic::BoxSet`], width ≤ 2): one box under a
//! single output-rank partition, and the L-shaped two-box sets that
//! row+column (P×Q) output tilings produce. The whole backward pass
//! collapses to the closed-form interval arithmetic of
//! [`crate::analysis::symbolic`]: per level, the first/steady/ragged-last
//! tile footprints and per-tensor transfer/reuse/occupancy counts are
//! derived from the composed `AffineMaps` in O(width² · dims) per set
//! operation, with no region algebra at all. The union calculus is *exact
//! or refuses*: the moment any operation would exceed the width bound the
//! walk bails out and the evaluation restarts on tier 2 — so tier 1 is an
//! accelerator, never an approximation. Combined with the steady-state
//! jumps below, a provable mapping evaluates in O(levels) leaf visits.
//! Which jumps fired at union width ≥ 2, and the peak/per-level widths, are
//! reported through [`super::PathCounts`]'s multibox counters.
//!
//! **Tier 2 — steady-state jumps over the region walk.** The walk recurses
//! over levels on general [`crate::poly::Region`] unions. At each level the
//! engine skips interior children either on a static proof
//! ([`crate::analysis::prove_levels`]) or by *certifying* steady state
//! empirically: two consecutive children whose exit availability states are
//! exact translates of each other (per tensor, box-for-box). All region
//! algebra in the backward pass is translation-equivariant — images and
//! preimages of translated boxes are translated images (`poly::affine`
//! never clips on *surjective* producer chains, which the session verifies
//! once) — so once two consecutive children match, every further interior
//! child is the translate of the last one: its metric contributions are
//! identical integers and its exit state is one more translate. The engine
//! then *jumps*: contributions are added `n`-fold, availability is shifted
//! in closed form, and the pipeline recurrence is advanced by an exact
//! max-plus [`super::latency::TransferMatrix`] power.
//!
//! **Tier 3 — reference walk.** Certification is purely observational, so
//! any mapping that never reaches steady state (degenerate counts,
//! monotone-growth retention-0 tensors under a moving schedule,
//! non-surjective chains) silently degrades to the exhaustive box-by-box
//! walk with identical results. [`EvalOptions::force_reference`] pins an
//! evaluation to this tier; it remains the oracle in the property tests.
//!
//! Which tiers fired is reported in [`Metrics::path`]
//! ([`super::PathCounts`]): whether the symbolic walk covered the whole
//! evaluation, how many jumps were proven vs. empirically certified, and
//! how many leaf iterations were actually walked.
//!
//! All quantities accumulated during any tier are integers, flowing through
//! the *shared* [`accumulate_leaf`] accumulation; derived `f64` metrics
//! (energy, NoC hop-words) are computed once at the end from the integer
//! totals, which is what makes every tier bit-identical to
//! [`Evaluator::evaluate_reference`](super::Evaluator::evaluate_reference)
//! rather than merely close.

use super::backward::{iter_backward_into, window_needs_into, BackwardScratch, WindowNeeds};
use super::intra::operand_slot_counts;
use super::latency::{memory_cycles, PipelineLatency, TransferMatrix};
use super::metrics::{EnergyBreakdown, Metrics, PathCounts};
use super::walk::TileWindows;
use crate::analysis::symbolic::{set_needs_into, BoxSet, SetScratch};
use crate::analysis::{objective_floors, prove_levels, LevelProof, ObjectiveFloors, SessionStatics};
use crate::arch::{energy, Arch};
use crate::einsum::{FusionSet, TensorKind};
use crate::mapping::{InterLayerMapping, IntraLayerMapping, Parallelism};
use crate::poly::{IBox, Region};

/// Evaluation options.
#[derive(Debug, Clone, Default)]
pub struct EvalOptions {
    /// Per-layer intra-layer mappings; derived by
    /// [`IntraLayerMapping::default_for`] when absent.
    pub intra: Option<Vec<IntraLayerMapping>>,
    /// Force the exhaustive reference walk (disable the steady-state
    /// fast path). Results are bit-identical either way; this exists for
    /// verification and benchmarking.
    pub force_reference: bool,
    /// Disable the tier-1 symbolic box walk (keep the tier-2 region walk
    /// with steady-state jumps). Results are bit-identical either way; this
    /// exists for verification and benchmarking.
    pub no_symbolic: bool,
}

/// Evaluate one mapping. Errors on structurally invalid inputs; capacity
/// overflow is reported via [`Metrics::capacity_ok`], not an error, so
/// searches can still rank infeasible points.
///
/// This is the one-shot convenience path: it re-validates the fusion set and
/// architecture and re-derives intra-layer defaults on every call. Hot loops
/// evaluating many mappings of the same workload should hold a
/// [`super::Evaluator`] session instead, which performs that work once.
pub fn evaluate(
    fs: &FusionSet,
    arch: &Arch,
    mapping: &InterLayerMapping,
    opts: &EvalOptions,
) -> Result<Metrics, String> {
    fs.validate()?;
    arch.validate()?;
    let intra = resolve_intra(fs, arch, opts.intra.as_deref())?;
    let cache = SessionCache::build(fs, arch, &intra);
    let mut scratch = EvalScratch::default();
    evaluate_prevalidated(
        fs,
        arch,
        mapping,
        &cache,
        &mut scratch,
        opts.force_reference,
        opts.no_symbolic,
    )
}

/// Check (or derive defaults for) the per-layer intra-layer mappings.
pub(crate) fn resolve_intra(
    fs: &FusionSet,
    arch: &Arch,
    intra: Option<&[IntraLayerMapping]>,
) -> Result<Vec<IntraLayerMapping>, String> {
    let n = fs.num_layers();
    match intra {
        Some(v) => {
            if v.len() != n {
                return Err(format!("expected {n} intra mappings, got {}", v.len()));
            }
            for (e, im) in fs.einsums.iter().zip(v) {
                im.validate(e, arch.noc.num_pes())?;
            }
            Ok(v.to_vec())
        }
        None => Ok(fs
            .einsums
            .iter()
            .map(|e| IntraLayerMapping::default_for(e, arch.noc.num_pes()))
            .collect()),
    }
}

/// Effective parallel MACs per layer (spatial fanout, capped by the array).
pub(crate) fn fanouts(intra: &[IntraLayerMapping], arch: &Arch) -> Vec<i64> {
    intra
        .iter()
        .map(|im| im.fanout().clamp(1, arch.compute.macs))
        .collect()
}

// ------------------------------------------------------- session constants --

/// Per-input-slot action-count constants (mapping-independent — derived from
/// the access projections, the intra-layer spatialization, and the NoC).
#[derive(Debug, Clone)]
struct InputConst {
    /// Dims of the layer's iteration space absent from this input's
    /// projection (candidates for register-level temporal reuse).
    reuse_dims: Vec<usize>,
    /// Spatial multicast factor (PEs sharing each GLB read).
    multicast: i64,
    /// NoC hop cost per multicast read (`NocSpec::multicast_hops`).
    hops: f64,
}

/// Everything about a (fusion set, architecture, intra) triple the walk
/// needs but that no mapping changes. The [`super::Evaluator`] builds this
/// once per session.
#[derive(Debug, Clone)]
pub(crate) struct SessionCache {
    /// Per-layer per-input-slot constants.
    layer_inputs: Vec<Vec<InputConst>>,
    /// Flat offset of layer `t`'s first input slot in the NoC read counters.
    noc_slot_offset: Vec<usize>,
    num_slots: usize,
    /// Whether the register file can hold at least one word (else no reuse).
    rf_gt1: bool,
    /// Per-layer compute energy per op (pJ).
    op_energy: Vec<f64>,
    /// Per-layer effective parallel MACs.
    fanout: Vec<i64>,
    /// Cached `einsums[t].domain()` per layer.
    domains: Vec<IBox>,
    /// Producer chains are surjective (every producer's output image covers
    /// its tensor), so backward preimages never clip and the steady-state
    /// translation argument is exact. Checked once; gates the fast path.
    surjective: bool,
    /// Dims of the last layer referenced by its output access; partitions on
    /// any other dim revisit output tiles (reduction-rank partitioning).
    out_dims: Vec<usize>,
    /// Whether the einsums form a pure chain (each output consumed by
    /// exactly the next layer). Gates the symbolic box walk: on chains the
    /// backward needs sweep provably stays single-box per tensor.
    chain: bool,
    /// Producing layer per tensor (`usize::MAX` = off-chip source), for the
    /// symbolic backward pass's consumer-to-producer routing.
    producer: Vec<usize>,
    /// Symbolic footprint-movement structure (powers the static steady-state
    /// prover, which replaces the empirical certification where it succeeds).
    pub(crate) statics: SessionStatics,
    /// Closed-form metric floors of this session (powers search pruning).
    pub(crate) floors: ObjectiveFloors,
}

impl SessionCache {
    pub(crate) fn build(fs: &FusionSet, arch: &Arch, intra: &[IntraLayerMapping]) -> SessionCache {
        let rf_words = arch
            .levels
            .get(2)
            .and_then(|l| l.capacity_bytes)
            .map(|b| (b / arch.word_bytes).max(1))
            .unwrap_or(1);
        let rf_gt1 = rf_words > 1;

        let mut layer_inputs = Vec::with_capacity(fs.num_layers());
        let mut noc_slot_offset = Vec::with_capacity(fs.num_layers());
        let mut num_slots = 0usize;
        for (t, e) in fs.einsums.iter().enumerate() {
            noc_slot_offset.push(num_slots);
            let mut slots = Vec::with_capacity(e.inputs.len());
            for acc in &e.inputs {
                let proj = acc.map.referenced_dims();
                let reuse_dims = (0..e.ndim()).filter(|d| !proj.contains(d)).collect();
                let mut multicast = 1i64;
                for &(d, f) in &intra[t].spatial {
                    if !proj.contains(&d) {
                        multicast *= f;
                    }
                }
                slots.push(InputConst {
                    reuse_dims,
                    multicast,
                    hops: arch.noc.multicast_hops(multicast),
                });
            }
            num_slots += slots.len();
            layer_inputs.push(slots);
        }

        let op_energy = fs
            .einsums
            .iter()
            .map(|e| energy::op_energy_pj(e.op_kind, arch.compute.mac_energy_pj))
            .collect();
        let domains: Vec<IBox> = fs.einsums.iter().map(|e| e.domain()).collect();

        let statics = SessionStatics::build(fs);
        let surjective = statics.surjective;
        let out_dims = statics.out_dims.clone();
        let fanout = fanouts(intra, arch);
        let floors = objective_floors(fs, &fanout, &op_energy);
        let chain = fs.is_chain();
        let mut producer = vec![usize::MAX; fs.tensors.len()];
        for (t, e) in fs.einsums.iter().enumerate() {
            producer[e.output.tensor.0] = t;
        }

        SessionCache {
            layer_inputs,
            noc_slot_offset,
            num_slots,
            rf_gt1,
            op_energy,
            fanout,
            domains,
            surjective,
            out_dims,
            chain,
            producer,
            statics,
            floors,
        }
    }
}

// ------------------------------------------------------------ accumulators --

/// Integer metric accumulators. Everything here is *additive* across
/// iterations, so a certified steady-state run of `n` identical children is
/// applied as `n ×` the delta of one child. Maxima (occupancy peaks) live
/// outside, in [`EvalScratch`]: steady-state children repeat values the
/// representative already contributed, so jumps never change a max.
#[derive(Debug, Clone, Default)]
struct Accum {
    iterations: i64,
    seq_cycles: i64,
    glb_reads: i64,
    glb_writes: i64,
    rf_reads: i64,
    rf_writes: i64,
    offchip_reads: i64,
    offchip_writes: i64,
    op_counts: Vec<i64>,
    /// GLB reads per (layer, input slot), flattened by
    /// `SessionCache::noc_slot_offset` — converted to NoC hop-words once at
    /// the end (keeping the walk integer-only).
    noc_reads: Vec<i64>,
    per_tensor_offchip: Vec<i64>,
    /// Accumulated fresh volume per tensor (recompute source for
    /// intermediates).
    fresh_acc: Vec<i64>,
}

impl Accum {
    fn prepare(&mut self, n: usize, nt: usize, slots: usize) {
        self.iterations = 0;
        self.seq_cycles = 0;
        self.glb_reads = 0;
        self.glb_writes = 0;
        self.rf_reads = 0;
        self.rf_writes = 0;
        self.offchip_reads = 0;
        self.offchip_writes = 0;
        reset_counts(&mut self.op_counts, n);
        reset_counts(&mut self.noc_reads, slots);
        reset_counts(&mut self.per_tensor_offchip, nt);
        reset_counts(&mut self.fresh_acc, nt);
    }

    /// Snapshot into `dst`, reusing its storage.
    fn save_into(&self, dst: &mut Accum) {
        dst.iterations = self.iterations;
        dst.seq_cycles = self.seq_cycles;
        dst.glb_reads = self.glb_reads;
        dst.glb_writes = self.glb_writes;
        dst.rf_reads = self.rf_reads;
        dst.rf_writes = self.rf_writes;
        dst.offchip_reads = self.offchip_reads;
        dst.offchip_writes = self.offchip_writes;
        dst.op_counts.clone_from(&self.op_counts);
        dst.noc_reads.clone_from(&self.noc_reads);
        dst.per_tensor_offchip.clone_from(&self.per_tensor_offchip);
        dst.fresh_acc.clone_from(&self.fresh_acc);
    }

    /// Add `mult` further copies of the delta accumulated since `snap`
    /// (i.e. `self += (self − snap) · mult`).
    fn add_scaled(&mut self, snap: &Accum, mult: i64) {
        self.iterations += (self.iterations - snap.iterations) * mult;
        self.seq_cycles += (self.seq_cycles - snap.seq_cycles) * mult;
        self.glb_reads += (self.glb_reads - snap.glb_reads) * mult;
        self.glb_writes += (self.glb_writes - snap.glb_writes) * mult;
        self.rf_reads += (self.rf_reads - snap.rf_reads) * mult;
        self.rf_writes += (self.rf_writes - snap.rf_writes) * mult;
        self.offchip_reads += (self.offchip_reads - snap.offchip_reads) * mult;
        self.offchip_writes += (self.offchip_writes - snap.offchip_writes) * mult;
        scale_vec(&mut self.op_counts, &snap.op_counts, mult);
        scale_vec(&mut self.noc_reads, &snap.noc_reads, mult);
        scale_vec(&mut self.per_tensor_offchip, &snap.per_tensor_offchip, mult);
        scale_vec(&mut self.fresh_acc, &snap.fresh_acc, mult);
    }
}

fn reset_counts(v: &mut Vec<i64>, len: usize) {
    v.clear();
    v.resize(len, 0);
}

fn scale_vec(cur: &mut [i64], snap: &[i64], mult: i64) {
    for (a, b) in cur.iter_mut().zip(snap) {
        *a += (*a - b) * mult;
    }
}

/// Retention-window cache slot: the data needs of one level-`j` prefix
/// window, reused while the prefix is unchanged.
#[derive(Debug, Clone, Default)]
struct CacheSlot {
    valid: bool,
    prefix: Vec<i64>,
    needs: WindowNeeds,
}

/// The symbolic walk's counterpart of [`CacheSlot`]: per-tensor needs
/// *box sets* of one level-`j` prefix window.
#[derive(Debug, Clone, Default)]
struct SymSlot {
    valid: bool,
    prefix: Vec<i64>,
    data: Vec<BoxSet>,
}

/// Reusable evaluation state. Owned (pooled) by the [`super::Evaluator`]
/// session so that the per-iteration hot path of the walk — availability
/// regions, backward-pass regions, window boxes, the iteration index, and
/// all accumulators — performs no heap allocation after warm-up.
#[derive(Debug, Clone, Default)]
pub(crate) struct EvalScratch {
    avail: Vec<Region>,
    idx: Vec<i64>,
    tile_lat: Vec<i64>,
    prev_occ: Vec<i64>,
    occ_max: Vec<i64>,
    occ_peak: i64,
    win: IBox,
    prefix_win: IBox,
    out_box: IBox,
    bbox: IBox,
    bw: BackwardScratch,
    cache_slots: Vec<CacheSlot>,
    acc: Accum,
    pipe: PipelineLatency,
    /// Transfer matrices currently recording a candidate steady child, one
    /// per ancestor level that is mid-certification.
    rec_stack: Vec<TransferMatrix>,
    /// Per level: availability snapshot at the end of the previous child.
    exit_snap: Vec<Vec<Region>>,
    /// Per level: accumulator snapshot at the start of the candidate child.
    acc_snap: Vec<Accum>,
    /// Per tensor: derived translation offsets of a certified run.
    delta: Vec<Vec<i64>>,

    // ---- symbolic (tier-1) box-walk shadows of the region state ----
    /// Per-tensor availability as a bounded box union (output-fmap entries
    /// unused: under the `out_exempt` gate distinct leaves write disjoint
    /// tiles, so output availability never feeds back into any metric).
    sym_avail: Vec<BoxSet>,
    /// Per-tensor pending producer requests (`BackwardScratch::pending`'s
    /// box-set twin).
    sym_pend: Vec<BoxSet>,
    /// Retention-window needs sets per level prefix.
    sym_slots: Vec<SymSlot>,
    /// Per level: availability snapshot at the end of the previous child.
    sym_exit: Vec<Vec<BoxSet>>,
    /// Per-tensor availability volumes of the current leaf, filled by
    /// whichever walk ran it and read by the shared [`accumulate_leaf`].
    occ_vol: Vec<i64>,
    /// Set temporaries of the symbolic backward pass.
    sym_ops: BoxSet,
    sym_need: BoxSet,
    sym_fr: BoxSet,
    /// Single-box image temporary of the set calculus.
    sym_tmp: IBox,
    /// Shared scratch of every [`BoxSet`] operation.
    sym_sc: SetScratch,

    // ---- per-path fire counters (reported via `Metrics::path`) ----
    /// Steady-state jumps taken on a static proof.
    ctr_proven: i64,
    /// Steady-state jumps taken after empirical certification.
    ctr_certified: i64,
    /// Leaf iterations actually walked.
    ctr_walked: i64,
    /// Proven jumps taken while some availability union held ≥ 2 boxes.
    ctr_mb_proven: i64,
    /// Certified jumps taken while some availability union held ≥ 2 boxes.
    ctr_mb_certified: i64,
    /// Per schedule level: the widest availability union observed at any
    /// child boundary of that level during the symbolic walk.
    level_width: Vec<i64>,
    /// Widest box union the symbolic walk ever held — availability at
    /// boundaries plus the transient ops/needs/fresh/pending sets inside
    /// each leaf's backward pass.
    peak_width: i64,
}

impl EvalScratch {
    fn prepare(&mut self, fs: &FusionSet, cache: &SessionCache, k: usize, pipeline: bool) {
        let n = fs.num_layers();
        let nt = fs.tensors.len();
        self.avail.resize_with(nt, || Region::empty(0));
        for (x, t) in fs.tensors.iter().enumerate() {
            self.avail[x].reset(t.ndim());
        }
        reset_counts(&mut self.idx, k);
        reset_counts(&mut self.tile_lat, n);
        reset_counts(&mut self.prev_occ, nt);
        reset_counts(&mut self.occ_max, nt);
        self.occ_peak = 0;
        self.cache_slots.resize_with(k + 1, CacheSlot::default);
        for slot in &mut self.cache_slots {
            slot.valid = false;
        }
        self.acc.prepare(n, nt, cache.num_slots);
        if pipeline {
            self.pipe.reset(n);
        }
        self.rec_stack.clear();
        self.exit_snap.resize_with(k, Vec::new);
        for snap in &mut self.exit_snap {
            snap.resize_with(nt, || Region::empty(0));
        }
        self.acc_snap.resize_with(k, Accum::default);
        self.delta.resize_with(nt, Vec::new);

        self.sym_avail.resize_with(nt, BoxSet::default);
        self.sym_pend.resize_with(nt, BoxSet::default);
        for (x, t) in fs.tensors.iter().enumerate() {
            self.sym_avail[x].reset_empty(t.ndim());
            self.sym_pend[x].reset_empty(t.ndim());
        }
        self.sym_slots.resize_with(k + 1, SymSlot::default);
        for slot in &mut self.sym_slots {
            slot.valid = false;
        }
        self.sym_exit.resize_with(k, Vec::new);
        for snap in &mut self.sym_exit {
            snap.resize_with(nt, BoxSet::default);
        }
        reset_counts(&mut self.occ_vol, nt);
        self.ctr_proven = 0;
        self.ctr_certified = 0;
        self.ctr_walked = 0;
        self.ctr_mb_proven = 0;
        self.ctr_mb_certified = 0;
        reset_counts(&mut self.level_width, k);
        self.peak_width = 0;
    }
}

// ------------------------------------------------------------------ walker --

/// Immutable per-call context of one walk.
struct Ctx<'a> {
    fs: &'a FusionSet,
    mapping: &'a InterLayerMapping,
    cache: &'a SessionCache,
    tw: TileWindows,
    counts: Vec<i64>,
    retention: Vec<usize>,
    k: usize,
    n: usize,
    nt: usize,
    pipeline: bool,
    /// Master fast-path gate (surjective chain, not forced off).
    fast: bool,
    /// The final output's availability may be translate-materialized across
    /// jumps: true iff no partition is on a reduction rank, so output tiles
    /// never revisit and "already written" never feeds back into a metric.
    out_exempt: bool,
    /// Per-level static steady-state proofs (`analysis::prove_levels`). A
    /// `Some` level jumps without the empirical two-child certification.
    proof: Vec<Option<LevelProof>>,
}

/// The schedule walk itself. Assumes `fs` and `arch` are already validated
/// and the session constants already built (the [`super::Evaluator`] session
/// caches them); only the per-call `mapping` is validated here.
pub(crate) fn evaluate_prevalidated(
    fs: &FusionSet,
    arch: &Arch,
    mapping: &InterLayerMapping,
    cache: &SessionCache,
    scratch: &mut EvalScratch,
    force_reference: bool,
    no_symbolic: bool,
) -> Result<Metrics, String> {
    mapping.validate(fs)?;

    let tw = TileWindows::new(fs, mapping);
    let counts = tw.counts().to_vec();
    let k = counts.len();
    let nt = fs.tensors.len();
    let retention: Vec<usize> = (0..nt)
        .map(|x| mapping.retention_for(crate::einsum::TensorId(x)))
        .collect();
    let pipeline = mapping.parallelism == Parallelism::Pipeline;
    let out_exempt = mapping
        .partitions
        .iter()
        .all(|p| cache.out_dims.contains(&p.dim));

    scratch.prepare(fs, cache, k, pipeline);
    let fast = cache.surjective && !force_reference;
    let proof = if fast {
        prove_levels(fs, &cache.statics, mapping, &counts)
    } else {
        vec![None; k]
    };
    let cx = Ctx {
        fs,
        mapping,
        cache,
        tw,
        counts,
        retention,
        k,
        n: fs.num_layers(),
        nt,
        pipeline,
        fast,
        out_exempt,
        proof,
    };
    // Tier 1: the symbolic box walk, gated on the structural facts that
    // keep every set within the bounded union width (surjective chain, all
    // partitions on output ranks). A runtime refusal anywhere in the union
    // calculus aborts the whole walk; the evaluation then restarts cleanly
    // on the region walk, so a bail costs one partial pass but never
    // exactness.
    let symbolic_ok = fast && !no_symbolic && cache.chain && cx.out_exempt;
    let symbolic = symbolic_ok && sym_level(&cx, scratch, 0, None);
    if !symbolic {
        if symbolic_ok {
            scratch.prepare(fs, cache, k, pipeline);
        }
        eval_level(&cx, scratch, 0, None);
    }
    let mut m = finalize(&cx, arch, scratch);
    m.path = PathCounts {
        symbolic,
        proven_jumps: scratch.ctr_proven,
        certified_jumps: scratch.ctr_certified,
        walked_iterations: scratch.ctr_walked,
        multibox_proven_jumps: scratch.ctr_mb_proven,
        multibox_certified_jumps: scratch.ctr_mb_certified,
        peak_union_width: if symbolic { scratch.peak_width } else { 0 },
        level_union_widths: if symbolic {
            scratch.level_width.clone()
        } else {
            Vec::new()
        },
        sym_refused: symbolic_ok && !symbolic,
    };
    Ok(m)
}

/// Walk all children of schedule level `l` (leaf iterations when `l == k`).
/// `entry_adv` is the advancing level of the subtree's first iteration
/// (`None` only for the very first iteration of the whole walk).
fn eval_level(cx: &Ctx, sc: &mut EvalScratch, l: usize, entry_adv: Option<usize>) {
    if l == cx.k {
        eval_leaf(cx, sc, entry_adv);
        return;
    }
    let c = cx.counts[l];
    sc.idx[l] = 0;
    eval_level(cx, sc, l + 1, entry_adv);
    if !(cx.fast && c >= 4) {
        for i in 1..c {
            sc.idx[l] = i;
            eval_level(cx, sc, l + 1, Some(l));
        }
        return;
    }

    if let Some(proof) = cx.proof[l].as_ref() {
        // Statically certified level: the prover showed that the exit states
        // of consecutive interior children are rigid translates with the
        // proven per-tensor deltas, so child 1 is evaluated as the steady
        // representative and the walk jumps straight to the ragged last
        // child — no exit snapshot, no box-for-box comparison. The jump
        // arithmetic is the same as the empirical path's, so results stay
        // bit-identical to the reference walk.
        {
            let (acc, snaps) = (&sc.acc, &mut sc.acc_snap);
            acc.save_into(&mut snaps[l]);
        }
        if cx.pipeline {
            sc.rec_stack.push(TransferMatrix::identity(cx.n));
        }
        sc.idx[l] = 1;
        eval_level(cx, sc, l + 1, Some(l));
        let rec = if cx.pipeline { sc.rec_stack.pop() } else { None };
        sc.ctr_proven += 1;
        let n_skip = c - 3;
        {
            let (acc, snaps) = (&mut sc.acc, &sc.acc_snap);
            acc.add_scaled(&snaps[l], n_skip);
        }
        if let Some(rec) = rec {
            let op = rec.power(n_skip);
            sc.pipe.apply_transfer(&op);
            for outer in sc.rec_stack.iter_mut() {
                outer.compose_with(&op);
            }
        }
        for (x, d) in proof.deltas.iter().enumerate() {
            let sd = &mut sc.delta[x];
            sd.clear();
            sd.extend(d.iter().map(|&v| v * n_skip));
            sc.avail[x].shift_assign(&sc.delta[x]);
        }
        sc.idx[l] = c - 1;
        eval_level(cx, sc, l + 1, Some(l));
        return;
    }

    // Steady-state certification: evaluate candidate children explicitly
    // until two consecutive children have exit states that are exact
    // translates (the first child is always cold; raggedness at deeper
    // levels can delay onset by one more child). `rep ≤ c − 3` keeps at
    // least one interior child to jump and the last child explicit.
    let max_rep = 2.min(c - 3);
    let mut next_child = 1i64;
    for rep in 1..=max_rep {
        for (x, snap) in sc.exit_snap[l].iter_mut().enumerate() {
            snap.clone_from(&sc.avail[x]);
        }
        {
            let (acc, snaps) = (&sc.acc, &mut sc.acc_snap);
            acc.save_into(&mut snaps[l]);
        }
        if cx.pipeline {
            sc.rec_stack.push(TransferMatrix::identity(cx.n));
        }
        sc.idx[l] = rep;
        eval_level(cx, sc, l + 1, Some(l));
        let rec = if cx.pipeline { sc.rec_stack.pop() } else { None };
        next_child = rep + 1;
        if certify(cx, sc, l) {
            sc.ctr_certified += 1;
            let n_skip = (c - 2) - rep;
            {
                let (acc, snaps) = (&mut sc.acc, &sc.acc_snap);
                acc.add_scaled(&snaps[l], n_skip);
            }
            if let Some(rec) = rec {
                let op = rec.power(n_skip);
                sc.pipe.apply_transfer(&op);
                for outer in sc.rec_stack.iter_mut() {
                    outer.compose_with(&op);
                }
            }
            for x in 0..cx.nt {
                for d in sc.delta[x].iter_mut() {
                    *d *= n_skip;
                }
                sc.avail[x].shift_assign(&sc.delta[x]);
            }
            next_child = c - 1;
            break;
        }
    }
    // Children not covered by a jump (certification failed or exhausted
    // candidates), then the (possibly ragged) last child, always explicit.
    for i in next_child..c {
        sc.idx[l] = i;
        eval_level(cx, sc, l + 1, Some(l));
    }
}

/// Compare the current availability (exit of the candidate child) against
/// the previous child's exit snapshot. On success, `sc.delta[x]` holds the
/// per-tensor translation offsets of one steady step.
fn certify(cx: &Ctx, sc: &mut EvalScratch, l: usize) -> bool {
    for x in 0..cx.nt {
        let nd = cx.fs.tensors[x].ndim();
        let d = &mut sc.delta[x];
        d.clear();
        d.resize(nd, 0);
        if cx.out_exempt && cx.fs.tensors[x].kind == TensorKind::OutputFmap {
            // "Already written" grows monotonically, but with no reduction
            // rank partitioned it never feeds back into any metric; shift it
            // with the window so its frontier stays exact.
            let part = &cx.mapping.partitions[l];
            for (o, expr) in cx.fs.last().output.map.exprs.iter().enumerate() {
                if expr.as_identity() == Some(part.dim) {
                    d[o] = part.tile;
                }
            }
            continue;
        }
        let prev = &sc.exit_snap[l][x];
        let cur = &sc.avail[x];
        if prev.complexity() != cur.complexity() {
            return false;
        }
        let (pb, cb) = match (prev.boxes().first(), cur.boxes().first()) {
            (None, None) => continue, // both empty: offset 0
            (Some(p), Some(c)) => (p, c),
            _ => return false,
        };
        for dim in 0..nd {
            d[dim] = cb.dims[dim].lo - pb.dims[dim].lo;
        }
        for (p, c) in prev.boxes().iter().zip(cur.boxes()) {
            for dim in 0..nd {
                if c.dims[dim].lo - p.dims[dim].lo != d[dim]
                    || c.dims[dim].hi - p.dims[dim].hi != d[dim]
                {
                    return false;
                }
            }
        }
    }
    true
}

/// One inter-layer iteration: retention invalidation, backward pass,
/// accumulation. Mirrors the paper's per-tile analysis (Fig 9/10).
fn eval_leaf(cx: &Ctx, sc: &mut EvalScratch, adv: Option<usize>) {
    let fs = cx.fs;

    // 1) Retention-window invalidation: a tensor retained at level j keeps
    //    only data inside its new level-j window once any level shallower
    //    than j advances (paper §III-D sliding retention). Output fmaps are
    //    exempt: their avail set tracks "already written" (outputs leave the
    //    chip exactly once; partial sums accumulate on-chip under the
    //    Buffets assumption) and their occupancy is the per-iteration drain
    //    tile, handled below.
    for x in 0..cx.nt {
        if fs.tensors[x].kind == TensorKind::OutputFmap {
            continue;
        }
        let j = cx.retention[x];
        if j == 0 {
            continue; // whole tensor retained; never invalidated
        }
        let changed = match adv {
            None => true,
            Some(a) => a < j,
        };
        if !changed {
            continue;
        }
        let prefix = &sc.idx[0..j];
        let slot = &mut sc.cache_slots[j];
        if !(slot.valid && slot.prefix == prefix) {
            cx.tw.window_into(prefix, &mut sc.prefix_win);
            window_needs_into(
                fs,
                &sc.prefix_win,
                &cx.cache.domains,
                &mut slot.needs,
                &mut sc.bbox,
            );
            slot.prefix.clear();
            slot.prefix.extend_from_slice(prefix);
            slot.valid = true;
        }
        if !sc.avail[x].is_empty() {
            sc.avail[x].intersect_assign(&sc.cache_slots[j].needs.data[x]);
        }
    }

    // 2) Backward pass with availability subtraction.
    cx.tw.window_into(&sc.idx, &mut sc.win);
    fs.last().output.map.image_box_into(&sc.win, &mut sc.out_box);
    let out_tile_vol = sc.out_box.volume();
    iter_backward_into(fs, &sc.win, &cx.cache.domains, &mut sc.avail, &mut sc.bw);

    // 3) Accumulate (shared with the symbolic walk, which fills `occ_vol`
    //    from its availability boxes instead).
    for x in 0..cx.nt {
        sc.occ_vol[x] = sc.avail[x].volume();
    }
    accumulate_leaf(cx, sc, out_tile_vol);
}

/// Metric accumulation of one inter-layer iteration — the single writer of
/// the integer accumulators for **both** the region walk and the symbolic
/// box walk, so the two tiers cannot diverge in accounting. Consumes the
/// backward results in `sc.bw` (op regions and per-tensor fresh volumes)
/// and the per-tensor availability volumes in `sc.occ_vol` (output-fmap
/// entries unused: outputs occupy their per-iteration drain tile,
/// `out_tile_vol`).
fn accumulate_leaf(cx: &Ctx, sc: &mut EvalScratch, out_tile_vol: i64) {
    let fs = cx.fs;
    sc.acc.iterations += 1;
    sc.ctr_walked += 1;
    for t in 0..cx.n {
        let ops = sc.bw.ops[t].volume();
        sc.acc.op_counts[t] += ops;
        let lat = ops.div_ceil(cx.cache.fanout[t]);
        sc.tile_lat[t] = lat;
        sc.acc.seq_cycles += lat;
        if ops == 0 {
            continue;
        }
        // Per-tile action counts (paper §IV-B): register-level temporal
        // reuse, NoC multicast, register-file traffic — the shared per-slot
        // definition (`intra::operand_slot_counts`), so model and simulator
        // cannot diverge.
        sc.bw.ops[t].bounding_box_into(&mut sc.bbox);
        let slots = &cx.cache.layer_inputs[t];
        let base = cx.cache.noc_slot_offset[t];
        for (s, ic) in slots.iter().enumerate() {
            let (pe_words, reads) =
                operand_slot_counts(cx.cache.rf_gt1, &ic.reuse_dims, ic.multicast, ops, &sc.bbox);
            sc.acc.glb_reads += reads;
            sc.acc.noc_reads[base + s] += reads;
            sc.acc.rf_writes += pe_words;
            sc.acc.rf_reads += ops;
        }
        // Results: partial sums accumulate in the PE register file and are
        // written to the GLB once per produced element.
        let produced = sc.bw.fresh[fs.einsums[t].output.tensor.0];
        sc.acc.glb_writes += produced;
        sc.acc.rf_reads += ops;
        sc.acc.rf_writes += ops;
    }
    if cx.pipeline {
        sc.pipe.push(&sc.tile_lat);
        for rec in sc.rec_stack.iter_mut() {
            rec.push_latencies(&sc.tile_lat);
        }
    }

    let mut total_occ = 0i64;
    for x in 0..cx.nt {
        let fresh = sc.bw.fresh[x];
        match fs.tensors[x].kind {
            TensorKind::InputFmap | TensorKind::Weight => {
                sc.acc.offchip_reads += fresh;
                sc.acc.per_tensor_offchip[x] += fresh;
                sc.acc.glb_writes += fresh; // DRAM -> GLB fill
            }
            TensorKind::OutputFmap => {
                sc.acc.offchip_writes += fresh;
                sc.acc.per_tensor_offchip[x] += fresh;
                sc.acc.glb_reads += fresh; // GLB -> DRAM drain
            }
            TensorKind::Intermediate => {
                sc.acc.fresh_acc[x] += fresh;
            }
        }
        // Occupancy after this iteration's updates. Output fmaps occupy only
        // their per-iteration drain tile (the accumulator for the current
        // window).
        let occ = if fs.tensors[x].kind == TensorKind::OutputFmap {
            out_tile_vol
        } else {
            sc.occ_vol[x]
        };
        let eff_occ = if cx.pipeline && fs.tensors[x].kind == TensorKind::Intermediate {
            // Next tile's production overlaps this tile's consumption.
            sc.prev_occ[x] + fresh
        } else {
            occ
        };
        sc.occ_max[x] = sc.occ_max[x].max(eff_occ);
        sc.prev_occ[x] = occ;
        total_occ += occ;
    }
    sc.occ_peak = sc.occ_peak.max(total_occ);
}

// --------------------------------------------------- symbolic (tier 1) ----

/// Widest availability union right now (output fmaps excluded: the walk
/// never materializes them).
fn sym_avail_width(cx: &Ctx, sc: &EvalScratch) -> i64 {
    let mut w = 0i64;
    for x in 0..cx.nt {
        if cx.fs.tensors[x].kind == TensorKind::OutputFmap {
            continue;
        }
        w = w.max(sc.sym_avail[x].width() as i64);
    }
    w
}

/// Record the current availability width against level `l`'s running max
/// (and the walk-wide peak). Called at every child boundary of `l`.
fn sym_record_width(cx: &Ctx, sc: &mut EvalScratch, l: usize) {
    let w = sym_avail_width(cx, sc);
    sc.level_width[l] = sc.level_width[l].max(w);
    sc.peak_width = sc.peak_width.max(w);
}

/// Tier-1 twin of [`eval_level`]: the same recursion, the same proven and
/// empirically-certified jump arithmetic, with every availability set held
/// as a bounded box union. Returns `false` the moment any set operation
/// refuses (result would exceed the union width bound); the caller then
/// re-prepares the scratch and reruns the whole evaluation on the region
/// walk, so a bail never loses exactness — only the time already spent.
fn sym_level(cx: &Ctx, sc: &mut EvalScratch, l: usize, entry_adv: Option<usize>) -> bool {
    if l == cx.k {
        return sym_leaf(cx, sc, entry_adv);
    }
    let c = cx.counts[l];
    sc.idx[l] = 0;
    if !sym_level(cx, sc, l + 1, entry_adv) {
        return false;
    }
    sym_record_width(cx, sc, l);
    if !(cx.fast && c >= 4) {
        for i in 1..c {
            sc.idx[l] = i;
            if !sym_level(cx, sc, l + 1, Some(l)) {
                return false;
            }
            sym_record_width(cx, sc, l);
        }
        return true;
    }

    if let Some(proof) = cx.proof[l].as_ref() {
        // Statically certified level — same jump as [`eval_level`]'s.
        {
            let (acc, snaps) = (&sc.acc, &mut sc.acc_snap);
            acc.save_into(&mut snaps[l]);
        }
        if cx.pipeline {
            sc.rec_stack.push(TransferMatrix::identity(cx.n));
        }
        sc.idx[l] = 1;
        if !sym_level(cx, sc, l + 1, Some(l)) {
            return false;
        }
        sym_record_width(cx, sc, l);
        let rec = if cx.pipeline { sc.rec_stack.pop() } else { None };
        sc.ctr_proven += 1;
        if sym_avail_width(cx, sc) >= 2 {
            sc.ctr_mb_proven += 1;
        }
        let n_skip = c - 3;
        {
            let (acc, snaps) = (&mut sc.acc, &sc.acc_snap);
            acc.add_scaled(&snaps[l], n_skip);
        }
        if let Some(rec) = rec {
            let op = rec.power(n_skip);
            sc.pipe.apply_transfer(&op);
            for outer in sc.rec_stack.iter_mut() {
                outer.compose_with(&op);
            }
        }
        for (x, d) in proof.deltas.iter().enumerate() {
            let sd = &mut sc.delta[x];
            sd.clear();
            sd.extend(d.iter().map(|&v| v * n_skip));
            if !sc.sym_avail[x].is_empty() {
                sc.sym_avail[x].shift_assign(&sc.delta[x]);
            }
        }
        sc.idx[l] = c - 1;
        if !sym_level(cx, sc, l + 1, Some(l)) {
            return false;
        }
        sym_record_width(cx, sc, l);
        return true;
    }

    // Empirical steady-state certification on the availability sets —
    // same protocol as [`eval_level`]'s, snapshotting box unions instead
    // of regions.
    let max_rep = 2.min(c - 3);
    let mut next_child = 1i64;
    for rep in 1..=max_rep {
        for (x, snap) in sc.sym_exit[l].iter_mut().enumerate() {
            snap.assign(&sc.sym_avail[x]);
        }
        {
            let (acc, snaps) = (&sc.acc, &mut sc.acc_snap);
            acc.save_into(&mut snaps[l]);
        }
        if cx.pipeline {
            sc.rec_stack.push(TransferMatrix::identity(cx.n));
        }
        sc.idx[l] = rep;
        if !sym_level(cx, sc, l + 1, Some(l)) {
            return false;
        }
        sym_record_width(cx, sc, l);
        let rec = if cx.pipeline { sc.rec_stack.pop() } else { None };
        next_child = rep + 1;
        if sym_certify(cx, sc, l) {
            sc.ctr_certified += 1;
            if sym_avail_width(cx, sc) >= 2 {
                sc.ctr_mb_certified += 1;
            }
            let n_skip = (c - 2) - rep;
            {
                let (acc, snaps) = (&mut sc.acc, &sc.acc_snap);
                acc.add_scaled(&snaps[l], n_skip);
            }
            if let Some(rec) = rec {
                let op = rec.power(n_skip);
                sc.pipe.apply_transfer(&op);
                for outer in sc.rec_stack.iter_mut() {
                    outer.compose_with(&op);
                }
            }
            for x in 0..cx.nt {
                for d in sc.delta[x].iter_mut() {
                    *d *= n_skip;
                }
                if !sc.sym_avail[x].is_empty() {
                    sc.sym_avail[x].shift_assign(&sc.delta[x]);
                }
            }
            next_child = c - 1;
            break;
        }
    }
    for i in next_child..c {
        sc.idx[l] = i;
        if !sym_level(cx, sc, l + 1, Some(l)) {
            return false;
        }
        sym_record_width(cx, sc, l);
    }
    true
}

/// [`certify`] on the availability sets: consecutive children's exit sets
/// must be rigid translates per tensor. [`BoxSet`]'s canonical form makes
/// the member correspondence positional, so the comparison is
/// representation-independent by construction.
fn sym_certify(cx: &Ctx, sc: &mut EvalScratch, l: usize) -> bool {
    for x in 0..cx.nt {
        let nd = cx.fs.tensors[x].ndim();
        let d = &mut sc.delta[x];
        d.clear();
        d.resize(nd, 0);
        if cx.out_exempt && cx.fs.tensors[x].kind == TensorKind::OutputFmap {
            // Same advance as [`certify`]'s: the output frontier moves one
            // tile per child (the symbolic walk never materializes it, so
            // the delta is recorded but shifts nothing).
            let part = &cx.mapping.partitions[l];
            for (o, expr) in cx.fs.last().output.map.exprs.iter().enumerate() {
                if expr.as_identity() == Some(part.dim) {
                    d[o] = part.tile;
                }
            }
            continue;
        }
        if !sc.sym_avail[x].translate_of(&sc.sym_exit[l][x], d) {
            return false;
        }
    }
    true
}

/// Tier-1 twin of [`eval_leaf`]: retention invalidation and the backward
/// pass on bounded box unions, then the shared [`accumulate_leaf`].
/// Returns `false` on any union-calculus refusal.
fn sym_leaf(cx: &Ctx, sc: &mut EvalScratch, adv: Option<usize>) -> bool {
    let fs = cx.fs;

    // 1) Retention-window invalidation — [`eval_leaf`] step 1 with the
    //    needs sets of the prefix window in place of needs regions.
    for x in 0..cx.nt {
        if fs.tensors[x].kind == TensorKind::OutputFmap {
            continue;
        }
        let j = cx.retention[x];
        if j == 0 {
            continue; // whole tensor retained; never invalidated
        }
        let changed = match adv {
            None => true,
            Some(a) => a < j,
        };
        if !changed {
            continue;
        }
        let prefix = &sc.idx[0..j];
        if !(sc.sym_slots[j].valid && sc.sym_slots[j].prefix == prefix) {
            cx.tw.window_into(prefix, &mut sc.prefix_win);
            let slot = &mut sc.sym_slots[j];
            if !set_needs_into(
                fs,
                &sc.prefix_win,
                &cx.cache.domains,
                &mut slot.data,
                &mut sc.sym_ops,
                &mut sc.sym_tmp,
                &mut sc.sym_sc,
            ) {
                return false;
            }
            slot.prefix.clear();
            slot.prefix.extend_from_slice(prefix);
            slot.valid = true;
        }
        if !sc.sym_avail[x].is_empty()
            && !sc.sym_avail[x].intersect_set_assign(&sc.sym_slots[j].data[x], &mut sc.sym_sc)
        {
            return false;
        }
    }

    // 2) Backward pass with availability subtraction, on box unions.
    cx.tw.window_into(&sc.idx, &mut sc.win);
    fs.last().output.map.image_box_into(&sc.win, &mut sc.out_box);
    let out_tile_vol = sc.out_box.volume();
    if !sym_backward(cx, sc) {
        return false;
    }

    // 3) Shared accumulation, reading availability volumes from the sets
    //    (disjoint members, so volumes add exactly).
    for x in 0..cx.nt {
        sc.occ_vol[x] = sc.sym_avail[x].volume();
    }
    accumulate_leaf(cx, sc, out_tile_vol);
    true
}

/// Set-specialized mirror of [`iter_backward_into`]: the same reverse
/// sweep, the same accounting order, with every region operation replaced
/// by its bounded-union counterpart — writing op regions (rebuilt from the
/// disjoint set members) and fresh volumes into `sc.bw` so
/// [`accumulate_leaf`] consumes identical state from either walk. Returns
/// `false` the moment any set would exceed the union width bound.
///
/// One deliberate divergence: the final output tensor's availability is
/// never materialized. Under the `out_exempt` gate distinct leaves write
/// pairwise-disjoint output tiles (no partition sits on a reduction rank,
/// so no output tile is ever revisited), hence `need − avail = need`
/// identically and the whole output frontier — a union of many boxes the
/// calculus could not hold — contributes nothing to any metric.
fn sym_backward(cx: &Ctx, sc: &mut EvalScratch) -> bool {
    let fs = cx.fs;
    let n = cx.n;
    sc.bw.ops.resize_with(n, || Region::empty(0));
    for (t, e) in fs.einsums.iter().enumerate() {
        sc.bw.ops[t].reset(e.ndim());
    }
    sc.bw.fresh.clear();
    sc.bw.fresh.resize(cx.nt, 0);
    for (x, tn) in fs.tensors.iter().enumerate() {
        sc.sym_pend[x].reset_empty(tn.ndim());
    }

    // Transient union-width watermark of this leaf (ops, needs, fresh,
    // pending, availability): full-retention mappings re-truncate their
    // availability to one box every leaf, so the multibox calculus shows up
    // only in these transient sets at row-wrap leaves.
    let mut w = 0i64;

    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        if t == n - 1 {
            sc.sym_ops.assign_box(&sc.win);
        } else {
            // Ops = preimage of the fresh output this layer's consumers
            // (all processed already) requested via the pending sets.
            // Preimages of disjoint data boxes are disjoint, so this
            // inherits the width bound and never refuses.
            sc.sym_pend[e.output.tensor.0].preimage_identity_into(
                &e.output.map,
                &cx.cache.domains[t],
                &mut sc.sym_ops,
                &mut sc.sym_tmp,
                &mut sc.sym_sc,
            );
        }
        if sc.sym_ops.is_empty() {
            continue;
        }
        w = w.max(sc.sym_ops.width() as i64);
        for m in sc.sym_ops.members() {
            sc.bw.ops[t].union_box(m);
        }

        // Freshly produced output data.
        let out = e.output.tensor.0;
        if !sc.sym_ops.image_into(&e.output.map, &mut sc.sym_need, &mut sc.sym_tmp, &mut sc.sym_sc)
        {
            return false;
        }
        w = w.max(sc.sym_need.width() as i64);
        if fs.tensors[out].kind == TensorKind::OutputFmap {
            // Disjoint tiles (see above): everything needed is fresh.
            sc.bw.fresh[out] += sc.sym_need.volume();
        } else {
            sc.sym_fr.assign(&sc.sym_need);
            if !sc.sym_fr.minus_set_assign(&sc.sym_avail[out], &mut sc.sym_sc) {
                return false;
            }
            sc.bw.fresh[out] += sc.sym_fr.volume();
            if !sc.sym_avail[out].union_set_assign(&sc.sym_fr, &mut sc.sym_sc) {
                return false;
            }
            w = w.max(sc.sym_fr.width() as i64).max(sc.sym_avail[out].width() as i64);
        }

        // Input needs: fresh parts are fetched (off-chip sources) or routed
        // to the upstream producer (intermediates).
        for acc in &e.inputs {
            let x = acc.tensor.0;
            if !sc.sym_ops.image_into(&acc.map, &mut sc.sym_need, &mut sc.sym_tmp, &mut sc.sym_sc) {
                return false;
            }
            let p = cx.cache.producer[x];
            if p != usize::MAX {
                debug_assert!(p < t, "fusion set is not in topological order");
                sc.sym_fr.assign(&sc.sym_need);
                if !sc.sym_fr.minus_set_assign(&sc.sym_avail[x], &mut sc.sym_sc) {
                    return false;
                }
                if !sc.sym_pend[x].is_empty() {
                    // Sibling consumers already requested part of this (only
                    // reachable off-chain; the chain gate makes this dead,
                    // but mirroring it keeps the twin faithful).
                    if !sc.sym_fr.minus_set_assign(&sc.sym_pend[x], &mut sc.sym_sc) {
                        return false;
                    }
                }
                if !sc.sym_pend[x].union_set_assign(&sc.sym_fr, &mut sc.sym_sc) {
                    return false;
                }
                w = w.max(sc.sym_fr.width() as i64).max(sc.sym_pend[x].width() as i64);
            } else {
                // Off-chip source: `|need − avail|` is exact for disjoint
                // unions, and `avail ∪ (need − avail) = avail ∪ need`.
                sc.bw.fresh[x] +=
                    sc.sym_need.volume() - sc.sym_need.overlap_volume_set(&sc.sym_avail[x]);
                if !sc.sym_avail[x].union_set_assign(&sc.sym_need, &mut sc.sym_sc) {
                    return false;
                }
                w = w.max(sc.sym_avail[x].width() as i64);
            }
            w = w.max(sc.sym_need.width() as i64);
        }
    }
    sc.peak_width = sc.peak_width.max(w);
    true
}

/// Assemble [`Metrics`] from the walk's integer accumulators. Shared by the
/// fast path and the reference walk, so derived `f64` metrics are computed
/// by the exact same expressions in both.
fn finalize(cx: &Ctx, arch: &Arch, sc: &EvalScratch) -> Metrics {
    let fs = cx.fs;
    let acc = &sc.acc;
    let mut m = Metrics {
        per_tensor_offchip: acc.per_tensor_offchip.clone(),
        per_tensor_occupancy: sc.occ_max.clone(),
        per_tensor_recompute: vec![0; cx.nt],
        ..Metrics::default()
    };
    m.iterations = acc.iterations;
    m.occupancy_peak = sc.occ_peak;

    // Recompute per tensor: produced minus size (intermediates only).
    for (x, t) in fs.tensors.iter().enumerate() {
        if t.kind == TensorKind::Intermediate {
            m.per_tensor_recompute[x] = (acc.fresh_acc[x] - t.size()).max(0);
        }
    }
    m.total_ops = acc.op_counts.iter().sum();
    m.recompute_ops = m.total_ops - fs.total_ops();
    m.offchip_reads = acc.offchip_reads;
    m.offchip_writes = acc.offchip_writes;
    m.sequential_compute_cycles = acc.seq_cycles;

    // Pipeline occupancy may exceed the per-iteration sum; use per-tensor
    // peaks as the capacity requirement (conservative for pipelines).
    if cx.pipeline {
        let per_tensor_sum: i64 = m.per_tensor_occupancy.iter().sum();
        m.occupancy_peak = m.occupancy_peak.max(per_tensor_sum);
    }

    // ---- latency ----
    m.compute_cycles = if cx.pipeline { sc.pipe.total() } else { acc.seq_cycles };
    let dram_words = m.offchip_reads + m.offchip_writes;
    let glb_words = acc.glb_reads + acc.glb_writes;
    let dram_cycles = memory_cycles(dram_words, arch.dram().bandwidth_words_per_cycle);
    let glb_cycles = memory_cycles(glb_words, arch.glb().bandwidth_words_per_cycle);
    m.memory_cycles = dram_cycles.max(glb_cycles);
    m.latency_cycles = m.compute_cycles.max(m.memory_cycles);

    // ---- energy (from the integer totals) ----
    m.glb_reads = acc.glb_reads;
    m.glb_writes = acc.glb_writes;
    let mut noc_hop_words = 0f64;
    for (t, slots) in cx.cache.layer_inputs.iter().enumerate() {
        let base = cx.cache.noc_slot_offset[t];
        for (s, ic) in slots.iter().enumerate() {
            noc_hop_words += acc.noc_reads[base + s] as f64 * ic.hops;
        }
    }
    m.noc_hop_words = noc_hop_words;
    let mut compute_pj = 0f64;
    for (t, &ops) in acc.op_counts.iter().enumerate() {
        compute_pj += ops as f64 * cx.cache.op_energy[t];
    }
    let dram = arch.dram();
    let glb = arch.glb();
    m.energy = EnergyBreakdown {
        dram_pj: m.offchip_reads as f64 * dram.read_energy_pj
            + m.offchip_writes as f64 * dram.write_energy_pj,
        glb_pj: acc.glb_reads as f64 * glb.read_energy_pj
            + acc.glb_writes as f64 * glb.write_energy_pj,
        rf_pj: arch
            .levels
            .get(2)
            .map(|rf| {
                acc.rf_reads as f64 * rf.read_energy_pj + acc.rf_writes as f64 * rf.write_energy_pj
            })
            .unwrap_or(0.0),
        compute_pj,
        noc_pj: noc_hop_words * arch.noc.hop_energy_pj,
    };

    // ---- capacity ----
    m.capacity_ok = match arch.glb_capacity() {
        None => true,
        Some(cap) => m.occupancy_peak * arch.word_bytes <= cap,
    };

    m
}
