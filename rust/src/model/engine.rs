//! The model evaluation engine: walks the inter-layer schedule once,
//! algebraically, accumulating all metrics.

use super::backward::{iter_backward, window_needs, WindowNeeds};
use super::intra::tile_counts;
use super::latency::{memory_cycles, PipelineLatency};
use super::metrics::{EnergyBreakdown, Metrics};
use super::walk::{IterWalk, TileWindows};
use crate::arch::{energy, Arch};
use crate::einsum::{FusionSet, TensorKind};
use crate::mapping::{InterLayerMapping, IntraLayerMapping, Parallelism};
use crate::poly::Region;

/// Evaluation options.
#[derive(Debug, Clone, Default)]
pub struct EvalOptions {
    /// Per-layer intra-layer mappings; derived by
    /// [`IntraLayerMapping::default_for`] when absent.
    pub intra: Option<Vec<IntraLayerMapping>>,
}

/// Evaluate one mapping. Errors on structurally invalid inputs; capacity
/// overflow is reported via [`Metrics::capacity_ok`], not an error, so
/// searches can still rank infeasible points.
///
/// This is the one-shot convenience path: it re-validates the fusion set and
/// architecture and re-derives intra-layer defaults on every call. Hot loops
/// evaluating many mappings of the same workload should hold a
/// [`super::Evaluator`] session instead, which performs that work once.
pub fn evaluate(
    fs: &FusionSet,
    arch: &Arch,
    mapping: &InterLayerMapping,
    opts: &EvalOptions,
) -> Result<Metrics, String> {
    fs.validate()?;
    arch.validate()?;
    let intra = resolve_intra(fs, arch, opts.intra.as_deref())?;
    let fanout = fanouts(&intra, arch);
    evaluate_prevalidated(fs, arch, mapping, &intra, &fanout)
}

/// Check (or derive defaults for) the per-layer intra-layer mappings.
pub(crate) fn resolve_intra(
    fs: &FusionSet,
    arch: &Arch,
    intra: Option<&[IntraLayerMapping]>,
) -> Result<Vec<IntraLayerMapping>, String> {
    let n = fs.num_layers();
    match intra {
        Some(v) => {
            if v.len() != n {
                return Err(format!("expected {n} intra mappings, got {}", v.len()));
            }
            for (e, im) in fs.einsums.iter().zip(v) {
                im.validate(e, arch.noc.num_pes())?;
            }
            Ok(v.to_vec())
        }
        None => Ok(fs
            .einsums
            .iter()
            .map(|e| IntraLayerMapping::default_for(e, arch.noc.num_pes()))
            .collect()),
    }
}

/// Effective parallel MACs per layer (spatial fanout, capped by the array).
pub(crate) fn fanouts(intra: &[IntraLayerMapping], arch: &Arch) -> Vec<i64> {
    intra
        .iter()
        .map(|im| im.fanout().clamp(1, arch.compute.macs))
        .collect()
}

/// The schedule walk itself. Assumes `fs` and `arch` are already validated
/// and `intra`/`fanout` already resolved (the [`super::Evaluator`] session
/// caches them); only the per-call `mapping` is validated here.
pub(crate) fn evaluate_prevalidated(
    fs: &FusionSet,
    arch: &Arch,
    mapping: &InterLayerMapping,
    intra: &[IntraLayerMapping],
    fanout: &[i64],
) -> Result<Metrics, String> {
    mapping.validate(fs)?;

    let n = fs.num_layers();
    let nt = fs.tensors.len();
    let tw = TileWindows::new(fs, mapping);
    let counts = tw.counts().to_vec();
    let k = counts.len();

    let retention: Vec<usize> = (0..nt)
        .map(|x| mapping.retention_for(crate::einsum::TensorId(x)))
        .collect();

    // ---- walk state ----
    let mut avail: Vec<Region> =
        fs.tensors.iter().map(|t| Region::empty(t.ndim())).collect();
    // Cached retained-window needs per retention level.
    let mut window_cache: Vec<Option<(Vec<i64>, WindowNeeds)>> = vec![None; k + 1];

    let mut m = Metrics {
        per_tensor_offchip: vec![0; nt],
        per_tensor_occupancy: vec![0; nt],
        per_tensor_recompute: vec![0; nt],
        ..Metrics::default()
    };
    let mut pipeline = PipelineLatency::new(n);
    let mut glb_reads = 0i64;
    let mut glb_writes = 0i64;
    let mut noc_hop_words = 0f64;
    let mut rf_reads = 0i64;
    let mut rf_writes = 0i64;
    let mut op_counts: Vec<i64> = vec![0; n];
    // For pipeline occupancy: producer of tile i+1 overlaps consumer of i.
    let mut prev_occ: Vec<i64> = vec![0; nt];
    let mut tile_lat = vec![0i64; n];

    for (idx, adv) in IterWalk::new(&counts) {
        m.iterations += 1;
        // 1) Retention-window invalidation: a tensor retained at level j
        //    keeps only data inside its new level-j window once any level
        //    shallower than j advances (paper §III-D sliding retention).
        //    Output fmaps are exempt: their avail set tracks "already
        //    written" (outputs leave the chip exactly once; partial sums
        //    accumulate on-chip under the Buffets assumption) and their
        //    occupancy is the per-iteration drain tile, handled below.
        for x in 0..nt {
            if fs.tensors[x].kind == TensorKind::OutputFmap {
                continue;
            }
            let j = retention[x];
            if j == 0 {
                continue; // whole tensor retained; never invalidated
            }
            let changed = match adv {
                None => true,
                Some(a) => a < j,
            };
            if !changed {
                continue;
            }
            let prefix = &idx[0..j];
            let needs_fresh = match &window_cache[j] {
                Some((p, _)) if p == prefix => false,
                _ => true,
            };
            if needs_fresh {
                let needs = window_needs(fs, &tw.window(prefix));
                window_cache[j] = Some((prefix.to_vec(), needs));
            }
            let (_, needs) = window_cache[j].as_ref().unwrap();
            if !avail[x].is_empty() {
                avail[x] = avail[x].intersect(&needs.data[x]);
            }
        }

        // 2) Backward pass with availability subtraction.
        let win = tw.window(&idx);
        let out_tile_vol = fs.last().output.map.image_box(&win).volume();
        let res = iter_backward(fs, &win, &mut avail);

        // 3) Accumulate metrics.
        for t in 0..n {
            let ops = res.ops[t].volume();
            op_counts[t] += ops;
            tile_lat[t] = div_ceil(ops, fanout[t]);
            m.sequential_compute_cycles += tile_lat[t];
            let e = &fs.einsums[t];
            let produced = res.fresh[e.output.tensor.0];
            let c = tile_counts(e, &intra[t], arch, &res.ops[t], produced);
            glb_reads += c.glb_reads;
            glb_writes += c.glb_writes;
            noc_hop_words += c.noc_hop_words;
            rf_reads += c.rf_reads;
            rf_writes += c.rf_writes;
            // Compute energy by op kind.
            m.energy.compute_pj +=
                ops as f64 * energy::op_energy_pj(e.op_kind, arch.compute.mac_energy_pj);
        }
        pipeline.push(&tile_lat);

        let mut total_occ = 0i64;
        for x in 0..nt {
            let fresh = res.fresh[x];
            match fs.tensors[x].kind {
                TensorKind::InputFmap | TensorKind::Weight => {
                    m.offchip_reads += fresh;
                    m.per_tensor_offchip[x] += fresh;
                    glb_writes += fresh; // DRAM -> GLB fill
                }
                TensorKind::OutputFmap => {
                    m.offchip_writes += fresh;
                    m.per_tensor_offchip[x] += fresh;
                    glb_reads += fresh; // GLB -> DRAM drain
                }
                TensorKind::Intermediate => {
                    m.per_tensor_recompute[x] += fresh;
                }
            }
            // Occupancy after this iteration's updates. Output fmaps occupy
            // only their per-iteration drain tile (the accumulator for the
            // current window).
            let occ = if fs.tensors[x].kind == TensorKind::OutputFmap {
                out_tile_vol
            } else {
                avail[x].volume()
            };
            let eff_occ = if mapping.parallelism == Parallelism::Pipeline
                && fs.tensors[x].kind == TensorKind::Intermediate
            {
                // Next tile's production overlaps this tile's consumption.
                prev_occ[x] + fresh
            } else {
                occ
            };
            m.per_tensor_occupancy[x] = m.per_tensor_occupancy[x].max(eff_occ);
            prev_occ[x] = occ;
            total_occ += occ;
        }
        m.occupancy_peak = m.occupancy_peak.max(total_occ);
    }

    // Recompute per tensor: produced minus size (intermediates only).
    for x in 0..nt {
        if fs.tensors[x].kind == TensorKind::Intermediate {
            m.per_tensor_recompute[x] =
                (m.per_tensor_recompute[x] - fs.tensors[x].size()).max(0);
        } else {
            m.per_tensor_recompute[x] = 0;
        }
    }
    m.total_ops = op_counts.iter().sum();
    m.recompute_ops = m.total_ops - fs.total_ops();

    // Pipeline occupancy may exceed the per-iteration sum; use per-tensor
    // peaks as the capacity requirement (conservative for pipelines).
    let per_tensor_sum: i64 = m.per_tensor_occupancy.iter().sum();
    m.occupancy_peak = m.occupancy_peak.max(if mapping.parallelism == Parallelism::Pipeline {
        per_tensor_sum
    } else {
        m.occupancy_peak
    });

    // ---- latency ----
    m.compute_cycles = match mapping.parallelism {
        Parallelism::Sequential => m.sequential_compute_cycles,
        Parallelism::Pipeline => pipeline.total(),
    };
    let dram_words = m.offchip_reads + m.offchip_writes;
    let glb_words = glb_reads + glb_writes;
    let dram_cycles = memory_cycles(dram_words, arch.dram().bandwidth_words_per_cycle);
    let glb_cycles = memory_cycles(glb_words, arch.glb().bandwidth_words_per_cycle);
    m.memory_cycles = dram_cycles.max(glb_cycles);
    m.latency_cycles = m.compute_cycles.max(m.memory_cycles);

    // ---- energy ----
    m.glb_reads = glb_reads;
    m.glb_writes = glb_writes;
    m.noc_hop_words = noc_hop_words;
    let dram = arch.dram();
    let glb = arch.glb();
    m.energy = EnergyBreakdown {
        dram_pj: m.offchip_reads as f64 * dram.read_energy_pj
            + m.offchip_writes as f64 * dram.write_energy_pj,
        glb_pj: glb_reads as f64 * glb.read_energy_pj
            + glb_writes as f64 * glb.write_energy_pj,
        rf_pj: arch
            .levels
            .get(2)
            .map(|rf| rf_reads as f64 * rf.read_energy_pj + rf_writes as f64 * rf.write_energy_pj)
            .unwrap_or(0.0),
        compute_pj: m.energy.compute_pj,
        noc_pj: noc_hop_words * arch.noc.hop_energy_pj,
    };

    // ---- capacity ----
    m.capacity_ok = match arch.glb_capacity() {
        None => true,
        Some(cap) => m.occupancy_peak * arch.word_bytes <= cap,
    };

    Ok(m)
}

fn div_ceil(a: i64, b: i64) -> i64 {
    (a + b - 1) / b
}
