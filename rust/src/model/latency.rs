//! Latency analysis (paper §IV-C1, Fig 12).
//!
//! Compute latency: per-iteration per-layer tile latencies are combined
//! either sequentially (sum) or as a pipeline. The pipeline combination is
//! the exact dataflow recurrence
//! `finish(s, i) = max(finish(s-1, i), finish(s, i-1)) + L_s(i)`
//! — equivalent to the paper's "arrange stages sequentially, subtract the
//! hidden latency" analysis, but exact for iteration-dependent tile
//! latencies (the paper notes op counts differ between iterations because
//! retained data is not recomputed).
//!
//! Memory latency: per-level transfer totals divided by level bandwidth; the
//! final latency is the max of compute and memory (Buffets-style decoupled
//! orchestration hides transfer latency behind compute, paper §IV-C1).

/// Incremental pipeline latency evaluator across `stages` layers.
#[derive(Debug, Clone)]
pub struct PipelineLatency {
    /// finish[s]: completion cycle of the most recent tile of stage s.
    finish: Vec<i64>,
}

impl PipelineLatency {
    pub fn new(stages: usize) -> Self {
        PipelineLatency { finish: vec![0; stages] }
    }

    /// Feed one iteration's per-stage tile latencies (stage 0 = first layer).
    pub fn push(&mut self, tile_latency: &[i64]) {
        debug_assert_eq!(tile_latency.len(), self.finish.len());
        let mut prev_stage_finish = 0i64;
        for (s, &l) in tile_latency.iter().enumerate() {
            let start = prev_stage_finish.max(self.finish[s]);
            self.finish[s] = start + l;
            prev_stage_finish = self.finish[s];
        }
    }

    /// Total latency so far.
    pub fn total(&self) -> i64 {
        self.finish.iter().copied().max().unwrap_or(0)
    }
}

/// Memory latency for one buffer level.
pub fn memory_cycles(words: i64, bandwidth_words_per_cycle: f64) -> i64 {
    if words == 0 || !bandwidth_words_per_cycle.is_finite() {
        return 0;
    }
    (words as f64 / bandwidth_words_per_cycle).ceil() as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_equals_pipeline_for_one_stage() {
        let mut p = PipelineLatency::new(1);
        for l in [5, 7, 3] {
            p.push(&[l]);
        }
        assert_eq!(p.total(), 15);
    }

    #[test]
    fn balanced_pipeline_hides_latency() {
        // Two stages, equal tile latency L, N iterations:
        // total = (N + 1) * L instead of 2*N*L.
        let mut p = PipelineLatency::new(2);
        let n = 10;
        for _ in 0..n {
            p.push(&[4, 4]);
        }
        assert_eq!(p.total(), (n + 1) * 4);
    }

    #[test]
    fn unbalanced_pipeline_bound_by_slow_stage() {
        let mut p = PipelineLatency::new(2);
        let n = 100;
        for _ in 0..n {
            p.push(&[2, 10]);
        }
        // Slow stage dominates: total ≈ first fill (2) + n*10.
        assert_eq!(p.total(), 2 + n * 10);
    }

    #[test]
    fn iteration_dependent_latencies() {
        // First tile bigger (halo): the recurrence handles ragged schedules.
        let mut p = PipelineLatency::new(2);
        p.push(&[6, 4]);
        p.push(&[4, 4]);
        p.push(&[4, 4]);
        // stage0: 6,10,14; stage1: 10,14,18.
        assert_eq!(p.total(), 18);
    }

    #[test]
    fn memory_cycles_rounding() {
        assert_eq!(memory_cycles(100, 8.0), 13);
        assert_eq!(memory_cycles(0, 8.0), 0);
        assert_eq!(memory_cycles(100, f64::INFINITY), 0);
    }
}
