//! Latency analysis (paper §IV-C1, Fig 12).
//!
//! Compute latency: per-iteration per-layer tile latencies are combined
//! either sequentially (sum) or as a pipeline. The pipeline combination is
//! the exact dataflow recurrence
//! `finish(s, i) = max(finish(s-1, i), finish(s, i-1)) + L_s(i)`
//! — equivalent to the paper's "arrange stages sequentially, subtract the
//! hidden latency" analysis, but exact for iteration-dependent tile
//! latencies (the paper notes op counts differ between iterations because
//! retained data is not recomputed).
//!
//! Memory latency: per-level transfer totals divided by level bandwidth; the
//! final latency is the max of compute and memory (Buffets-style decoupled
//! orchestration hides transfer latency behind compute, paper §IV-C1).

/// Incremental pipeline latency evaluator across `stages` layers.
#[derive(Debug, Clone, Default)]
pub struct PipelineLatency {
    /// finish[s]: completion cycle of the most recent tile of stage s.
    finish: Vec<i64>,
}

impl PipelineLatency {
    /// A pipeline latency tracker over `stages` stages with nothing pushed yet.
    pub fn new(stages: usize) -> Self {
        PipelineLatency { finish: vec![0; stages] }
    }

    /// Reset to the start-of-walk state, reusing storage.
    pub fn reset(&mut self, stages: usize) {
        self.finish.clear();
        self.finish.resize(stages, 0);
    }

    /// Feed one iteration's per-stage tile latencies (stage 0 = first layer).
    pub fn push(&mut self, tile_latency: &[i64]) {
        debug_assert_eq!(tile_latency.len(), self.finish.len());
        let mut prev_stage_finish = 0i64;
        for (s, &l) in tile_latency.iter().enumerate() {
            let start = prev_stage_finish.max(self.finish[s]);
            self.finish[s] = start + l;
            prev_stage_finish = self.finish[s];
        }
    }

    /// The per-stage completion cycles.
    pub fn finish(&self) -> &[i64] {
        &self.finish
    }

    /// Advance the state through a (possibly repeated) block of pushes
    /// represented by its exact max-plus transfer matrix.
    pub fn apply_transfer(&mut self, m: &TransferMatrix) {
        m.apply(&mut self.finish);
    }

    /// Total latency so far.
    pub fn total(&self) -> i64 {
        self.finish.iter().copied().max().unwrap_or(0)
    }
}

/// Sentinel for "no path" entries of a [`TransferMatrix`] (max-plus −∞).
/// `i64::MIN / 4` leaves headroom so that adding a real latency to a
/// sentinel can never overflow or become competitive with a real entry.
const NEG: i64 = i64::MIN / 4;

fn is_neg(x: i64) -> bool {
    x < i64::MIN / 8
}

/// Exact max-plus transfer matrix of a sequence of [`PipelineLatency::push`]
/// calls.
///
/// One push with per-stage latencies `l` maps the finish vector `f` to
/// `f'[s] = max_{j ≤ s} (f[j] + Σ_{t=j..s} l[t])` — a max-plus affine map.
/// Such maps are closed under composition (max-plus matrix product), so an
/// arbitrary block of pushes is one matrix, and *repeating* the block
/// `n` times is the matrix power — which is how the steady-state fast path
/// advances a pipeline across thousands of identical tiles bit-exactly
/// without walking them (including unbalanced pipelines, where the naive
/// "finish deltas repeat" shortcut is wrong during transients).
#[derive(Debug, Clone)]
pub struct TransferMatrix {
    n: usize,
    /// Row-major: `a[j * n + s]` contributes `f[j] + a[j*n+s]` to `f'[s]`.
    a: Vec<i64>,
}

impl TransferMatrix {
    /// The identity map (empty block of pushes).
    pub fn identity(n: usize) -> Self {
        let mut a = vec![NEG; n * n];
        for j in 0..n {
            a[j * n + j] = 0;
        }
        TransferMatrix { n, a }
    }

    /// Right-compose with one push of per-stage latencies `l` (the push
    /// happens *after* the block already represented by `self`).
    pub fn push_latencies(&mut self, l: &[i64]) {
        debug_assert_eq!(l.len(), self.n);
        let n = self.n;
        for j in 0..n {
            let row = &mut self.a[j * n..(j + 1) * n];
            // new_row[s] = max_{r ≤ s} (row[r] + Σ_{t=r..s} l[t]), computed
            // with the same running recurrence as PipelineLatency::push.
            let mut g = NEG;
            for (s, &ls) in l.iter().enumerate() {
                let base = if is_neg(g) {
                    row[s]
                } else if is_neg(row[s]) {
                    g
                } else {
                    g.max(row[s])
                };
                g = if is_neg(base) { NEG } else { base + ls };
                row[s] = g;
            }
        }
    }

    /// Max-plus product: the map "`self`, then `other`".
    pub fn matmul(&self, other: &TransferMatrix) -> TransferMatrix {
        debug_assert_eq!(self.n, other.n);
        let n = self.n;
        let mut a = vec![NEG; n * n];
        for j in 0..n {
            for r in 0..n {
                let x = self.a[j * n + r];
                if is_neg(x) {
                    continue;
                }
                for s in 0..n {
                    let y = other.a[r * n + s];
                    if is_neg(y) {
                        continue;
                    }
                    let v = x + y;
                    let e = &mut a[j * n + s];
                    if v > *e {
                        *e = v;
                    }
                }
            }
        }
        TransferMatrix { n, a }
    }

    /// Right-compose in place: `self = self ⊗ other`.
    pub fn compose_with(&mut self, other: &TransferMatrix) {
        *self = self.matmul(other);
    }

    /// `self` applied `e` times (binary exponentiation; exact).
    pub fn power(&self, mut e: i64) -> TransferMatrix {
        debug_assert!(e >= 0);
        let mut result = TransferMatrix::identity(self.n);
        let mut base = self.clone();
        while e > 0 {
            if e & 1 == 1 {
                result = result.matmul(&base);
            }
            e >>= 1;
            if e > 0 {
                base = base.matmul(&base);
            }
        }
        result
    }

    /// Apply to a finish vector in place.
    pub fn apply(&self, f: &mut [i64]) {
        debug_assert_eq!(f.len(), self.n);
        let n = self.n;
        let mut out = vec![NEG; n];
        for (s, o) in out.iter_mut().enumerate() {
            for j in 0..n {
                let x = self.a[j * n + s];
                if is_neg(x) {
                    continue;
                }
                let v = f[j] + x;
                if v > *o {
                    *o = v;
                }
            }
        }
        f.copy_from_slice(&out);
    }
}

/// Memory latency for one buffer level.
pub fn memory_cycles(words: i64, bandwidth_words_per_cycle: f64) -> i64 {
    if words == 0 || !bandwidth_words_per_cycle.is_finite() {
        return 0;
    }
    (words as f64 / bandwidth_words_per_cycle).ceil() as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_equals_pipeline_for_one_stage() {
        let mut p = PipelineLatency::new(1);
        for l in [5, 7, 3] {
            p.push(&[l]);
        }
        assert_eq!(p.total(), 15);
    }

    #[test]
    fn balanced_pipeline_hides_latency() {
        // Two stages, equal tile latency L, N iterations:
        // total = (N + 1) * L instead of 2*N*L.
        let mut p = PipelineLatency::new(2);
        let n = 10;
        for _ in 0..n {
            p.push(&[4, 4]);
        }
        assert_eq!(p.total(), (n + 1) * 4);
    }

    #[test]
    fn unbalanced_pipeline_bound_by_slow_stage() {
        let mut p = PipelineLatency::new(2);
        let n = 100;
        for _ in 0..n {
            p.push(&[2, 10]);
        }
        // Slow stage dominates: total ≈ first fill (2) + n*10.
        assert_eq!(p.total(), 2 + n * 10);
    }

    #[test]
    fn iteration_dependent_latencies() {
        // First tile bigger (halo): the recurrence handles ragged schedules.
        let mut p = PipelineLatency::new(2);
        p.push(&[6, 4]);
        p.push(&[4, 4]);
        p.push(&[4, 4]);
        // stage0: 6,10,14; stage1: 10,14,18.
        assert_eq!(p.total(), 18);
    }

    #[test]
    fn memory_cycles_rounding() {
        assert_eq!(memory_cycles(100, 8.0), 13);
        assert_eq!(memory_cycles(0, 8.0), 0);
        assert_eq!(memory_cycles(100, f64::INFINITY), 0);
    }

    /// One push as a matrix must equal one explicit push from any state.
    #[test]
    fn transfer_matrix_single_push() {
        let l = [6, 4, 9];
        let mut m = TransferMatrix::identity(3);
        m.push_latencies(&l);
        for start in [[0, 0, 0], [5, 2, 40], [100, 0, 3]] {
            let mut p = PipelineLatency { finish: start.to_vec() };
            p.push(&l);
            let mut f = start.to_vec();
            m.apply(&mut f);
            assert_eq!(f, p.finish, "start {start:?}");
        }
    }

    /// Matrix powers must reproduce explicit repetition exactly — including
    /// unbalanced pipelines, where finish deltas stay non-uniform forever.
    #[test]
    fn transfer_matrix_power_matches_repetition() {
        for l in [vec![4, 4], vec![2, 10], vec![10, 1, 1], vec![3, 0, 7, 2]] {
            let n = l.len();
            let mut block = TransferMatrix::identity(n);
            block.push_latencies(&l);
            // A non-trivial warm start (partial fill + a straggler stage).
            let warm: Vec<i64> = (0..n as i64).map(|s| 50 + 13 * s).collect();
            let mut p = PipelineLatency::new(n);
            p.push(&warm);
            for reps in [1i64, 2, 3, 7, 100] {
                let mut explicit = p.clone();
                for _ in 0..reps {
                    explicit.push(&l);
                }
                let mut jumped = p.clone();
                jumped.apply_transfer(&block.power(reps));
                assert_eq!(jumped.finish, explicit.finish, "l={l:?} reps={reps}");
            }
        }
    }

    /// A mixed block (two different pushes) repeated via its matrix.
    #[test]
    fn transfer_matrix_block_power() {
        let (a, b) = ([6, 4], [4, 4]);
        let mut block = TransferMatrix::identity(2);
        block.push_latencies(&a);
        block.push_latencies(&b);
        let mut explicit = PipelineLatency::new(2);
        for _ in 0..13 {
            explicit.push(&a);
            explicit.push(&b);
        }
        let mut jumped = PipelineLatency::new(2);
        jumped.apply_transfer(&block.power(13));
        assert_eq!(jumped.finish, explicit.finish);
    }
}
