//! The LoopTree analytical model (paper §IV).
//!
//! Given a fusion set, an architecture, and an inter-layer mapping, the model
//! computes latency, energy, buffer occupancy, and off-chip transfers by
//! walking the inter-layer tile schedule *algebraically*: every quantity is
//! derived from exact rectilinear-region operations on operation and data
//! tiles (the paper's polyhedral analysis), never by enumerating individual
//! operations. The three analysis steps mirror the paper's Fig 9:
//!
//! 1. **Tile-shape analysis** ([`backward`], [`walk`]) — from the last
//!    layer's mapped tile, infer every layer's operation tiles and every
//!    tensor's data tiles through data dependencies, subtracting what
//!    retention keeps available (paper Fig 10). Recomputation and refetch
//!    fall out of the same subtraction (paper §III-D).
//! 2. **Per-tile action counts** ([`intra`]) — reads/writes per buffer
//!    level, MACs, NoC hops for each processed tile (Timeloop-style).
//! 3. **Final metrics** ([`latency`], [`energy`], [`metrics`]) — sequential
//!    or pipelined latency (hidden-latency analysis, paper Fig 12), energy
//!    from accelergy-lite action costs, peak occupancy, off-chip traffic.
//!
//! Two entry points: the free [`evaluate`] for one-off calls, and the
//! [`Evaluator`] session, which validates the (fusion set, architecture)
//! pair once and then evaluates many mappings cheaply — the API every search
//! and case-study sweep uses.
//!
//! Evaluation itself runs through a three-tier path hierarchy with
//! bit-identical results (see the `engine` module docs): the **symbolic box
//! walk** (default where it applies), which derives every tile class's
//! footprints and transfer counts in closed form from single-box interval
//! arithmetic; the **steady-state jump walk**, which classifies the
//! iteration space into first/steady/ragged-last tile classes per schedule
//! level and evaluates one representative per class over general regions;
//! and the **exhaustive reference walk**
//! ([`Evaluator::evaluate_reference`]), which visits every inter-layer
//! iteration and serves as the verification oracle. Which tiers fired is
//! reported in [`Metrics::path`] ([`PathCounts`]) and explained per level
//! by [`Evaluator::explain`] ([`EvalExplain`]).

mod backward;
mod engine;
mod evaluator;
mod intra;
mod latency;
mod metrics;
mod walk;

pub use backward::{window_needs, WindowNeeds};
pub use engine::{evaluate, EvalOptions};
pub use evaluator::{EvalExplain, Evaluator, LevelExplain};
pub use intra::{tile_counts_from, IntraCounts};
pub use metrics::{EnergyBreakdown, Metrics, PathCounts};
pub use walk::{IterWalk, TileWindows};

#[cfg(test)]
mod tests;
