use super::*;
use crate::arch::Arch;
use crate::einsum::{workloads, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};

fn eval(
    fs: &crate::einsum::FusionSet,
    mapping: &InterLayerMapping,
) -> Metrics {
    let arch = Arch::generic(100_000_000); // effectively unbounded
    evaluate(fs, &arch, mapping, &EvalOptions::default()).unwrap()
}

fn p2_mapping(fs: &crate::einsum::FusionSet, tile: i64) -> InterLayerMapping {
    let p2 = fs.last().rank_index(&format!("P{}", fs.num_layers())).unwrap();
    InterLayerMapping::tiled(vec![Partition { dim: p2, tile }], Parallelism::Sequential)
}

#[test]
fn untiled_fusion_is_algmin_no_recompute() {
    let fs = workloads::conv_conv(14, 8);
    let m = eval(&fs, &InterLayerMapping::untiled(Parallelism::Sequential));
    assert_eq!(m.recompute_ops, 0);
    assert_eq!(m.total_ops, fs.total_ops());
    assert_eq!(m.offchip_total(), fs.algmin_offchip_elems());
    // Whole intermediate retained: occupancy at least Fmap2 size.
    let fmap2 = &fs.tensors[2];
    assert_eq!(fmap2.kind, TensorKind::Intermediate);
    assert!(m.per_tensor_occupancy[2] >= fmap2.size());
}

#[test]
fn row_tiling_retained_is_algmin_with_small_buffers() {
    let fs = workloads::conv_conv(28, 8);
    let m = eval(&fs, &p2_mapping(&fs, 4));
    // Sliding retention across P2: no recompute, no refetch.
    assert_eq!(m.recompute_ops, 0, "unexpected recompute");
    assert_eq!(m.offchip_total(), fs.algmin_offchip_elems());
    // But intermediate occupancy is a band, much smaller than the fmap.
    let fmap2 = &fs.tensors[2];
    assert!(m.per_tensor_occupancy[2] < fmap2.size() / 2);
    // Output written exactly once.
    let out = fs.tensors_of_kind(TensorKind::OutputFmap)[0];
    assert_eq!(m.per_tensor_offchip[out.0], fs.tensor(out).size());
}

#[test]
fn recompute_appears_when_retention_too_deep() {
    // P2,Q2 tiling; retain the intermediate only at level 2 (small box):
    // vertical halo rows are recomputed on every P2 advance (paper Fig 8).
    let fs = workloads::conv_conv(28, 8);
    let last = fs.last();
    let p2 = last.rank_index("P2").unwrap();
    let q2 = last.rank_index("Q2").unwrap();
    let inter = TensorId(2);
    let deep = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }, Partition { dim: q2, tile: 4 }],
        Parallelism::Sequential,
    )
    .with_retention(inter, 2);
    let shallow = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }, Partition { dim: q2, tile: 4 }],
        Parallelism::Sequential,
    )
    .with_retention(inter, 1);

    let md = eval(&fs, &deep);
    let ms = eval(&fs, &shallow);
    assert!(md.recompute_ops > 0, "deep retention must recompute halos");
    assert_eq!(ms.recompute_ops, 0, "band retention must not recompute");
    // The trade-off: deep retention holds less of the intermediate.
    assert!(md.per_tensor_occupancy[inter.0] < ms.per_tensor_occupancy[inter.0]);
}

#[test]
fn fc_fusion_has_no_retention_recompute_choice() {
    // Paper §VI-C: fc+fc intermediate tiles never overlap, so recompute = 0
    // for every retention level.
    let fs = workloads::fc_fc(64, 128);
    let last = fs.last();
    let m2 = last.rank_index("M2").unwrap();
    let inter = TensorId(2);
    for lvl in [0usize, 1] {
        let m = InterLayerMapping::tiled(
            vec![Partition { dim: m2, tile: 16 }],
            Parallelism::Sequential,
        )
        .with_retention(inter, lvl);
        let r = eval(&fs, &m);
        assert_eq!(r.recompute_ops, 0, "retention level {lvl}");
    }
}

#[test]
fn channel_partitioning_full_input_footprint() {
    // Partitioning C2 (= M1) alone: every tile needs the whole Fmap1 (paper
    // Fig 3(b) / Table III "Full" reuse), so any retention level retains the
    // entirety of Fmap1 — it is fetched once but occupies its full size.
    let fs = workloads::conv_conv(14, 16);
    let c2 = fs.last().rank_index("C2").unwrap();
    let fmap1 = TensorId(0);
    let m = InterLayerMapping::tiled(
        vec![Partition { dim: c2, tile: 4 }],
        Parallelism::Sequential,
    )
    .with_retention(fmap1, 1);
    let r = eval(&fs, &m);
    assert_eq!(r.per_tensor_offchip[fmap1.0], fs.tensor(fmap1).size());
    assert!(r.per_tensor_occupancy[fmap1.0] >= fs.tensor(fmap1).size());
}

#[test]
fn outer_rank_revisit_refetches_unretained_input() {
    // Schedule C2,P2: row bands of Fmap1 are re-needed on every C2
    // iteration. Retained only at level 2 (the band), each C2 advance drops
    // the previous rows → Fmap1 is refetched once per C2 tile (paper §VI-B:
    // "if we do not want to refetch ... we must keep those tensors
    // on-chip").
    let fs = workloads::conv_conv(14, 16);
    let last = fs.last();
    let c2 = last.rank_index("C2").unwrap();
    let p2 = last.rank_index("P2").unwrap();
    let fmap1 = TensorId(0);
    let tiles = 4i64;
    let parts = vec![
        Partition { dim: c2, tile: 16 / tiles },
        Partition { dim: p2, tile: 4 },
    ];

    let refetch = InterLayerMapping::tiled(parts.clone(), Parallelism::Sequential)
        .with_retention(fmap1, 2);
    let r = eval(&fs, &refetch);
    assert_eq!(
        r.per_tensor_offchip[fmap1.0],
        fs.tensor(fmap1).size() * tiles
    );

    // Retained at level 1 (the C2 tile = full Fmap1): fetched once.
    let keep = InterLayerMapping::tiled(parts, Parallelism::Sequential)
        .with_retention(fmap1, 1);
    let k = eval(&fs, &keep);
    assert_eq!(k.per_tensor_offchip[fmap1.0], fs.tensor(fmap1).size());
    assert!(k.per_tensor_occupancy[fmap1.0] >= fs.tensor(fmap1).size());
    // The refetching mapping uses less Fmap1 buffer space.
    assert!(r.per_tensor_occupancy[fmap1.0] < k.per_tensor_occupancy[fmap1.0]);
}

#[test]
fn weights_fully_reused_under_row_partitioning() {
    // P2 partitioning: filters are needed by every tile; retained at any
    // level they're fetched once (the window footprint is the full filter).
    let fs = workloads::conv_conv(28, 8);
    let m = eval(&fs, &p2_mapping(&fs, 4));
    for (i, t) in fs.tensors.iter().enumerate() {
        if t.kind == TensorKind::Weight {
            assert_eq!(m.per_tensor_offchip[i], t.size(), "weight {}", t.name);
            assert!(m.per_tensor_occupancy[i] >= t.size());
        }
    }
}

#[test]
fn pipeline_latency_below_sequential() {
    let fs = workloads::conv_conv(28, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    let parts = vec![Partition { dim: p2, tile: 2 }];
    let seq = eval(
        &fs,
        &InterLayerMapping::tiled(parts.clone(), Parallelism::Sequential),
    );
    let pipe = eval(&fs, &InterLayerMapping::tiled(parts, Parallelism::Pipeline));
    assert!(pipe.compute_cycles < seq.compute_cycles);
    // Pipelining does not change work or transfers.
    assert_eq!(pipe.total_ops, seq.total_ops);
    assert_eq!(pipe.offchip_total(), seq.offchip_total());
    // But needs more simultaneous buffering for intermediates.
    assert!(pipe.occupancy_peak >= seq.occupancy_peak);
}

#[test]
fn capacity_check_against_arch() {
    let fs = workloads::conv_conv(28, 32);
    let mapping = InterLayerMapping::untiled(Parallelism::Sequential);
    let small = Arch::generic(1); // 1 KiB GLB
    let r = evaluate(&fs, &small, &mapping, &EvalOptions::default()).unwrap();
    assert!(!r.capacity_ok);
    let big = Arch::generic(1 << 20);
    let r = evaluate(&fs, &big, &mapping, &EvalOptions::default()).unwrap();
    assert!(r.capacity_ok);
}

#[test]
fn three_layer_compounding_recompute() {
    // Paper §VI-E: recomputing a later fmap compounds recomputation in
    // earlier layers.
    let fs = workloads::conv_conv_conv(20, 4);
    let last = fs.last();
    let p3 = last.rank_index("P3").unwrap();
    let fmap2 = TensorId(2);
    let fmap3 = TensorId(4);
    assert_eq!(fs.tensor(fmap2).name, "Fmap2");
    assert_eq!(fs.tensor(fmap3).name, "Fmap3");
    let parts = vec![Partition { dim: p3, tile: 2 }];

    // Retain both: no recompute.
    let rr = eval(
        &fs,
        &InterLayerMapping::tiled(parts.clone(), Parallelism::Sequential),
    );
    assert_eq!(rr.recompute_ops, 0);

    // "Recompute X" = retain X only at the deep P3,Q3 level so its vertical
    // halo is recomputed on every P3 advance. Compare the four per-fmap
    // combinations (paper Fig 17's legend).
    let q3 = last.rank_index("Q3").unwrap();
    let parts2 = vec![
        Partition { dim: p3, tile: 2 },
        Partition { dim: q3, tile: 4 },
    ];
    let mk = |l2: usize, l3: usize| {
        eval(
            &fs,
            &InterLayerMapping::tiled(parts2.clone(), Parallelism::Sequential)
                .with_retention(fmap2, l2)
                .with_retention(fmap3, l3),
        )
    };
    let retain_both = mk(1, 1);
    let rec_f2 = mk(2, 1);
    let rec_f3 = mk(1, 2);
    let rec_both = mk(2, 2);
    assert_eq!(retain_both.recompute_ops, 0);
    assert!(rec_f2.recompute_ops > 0 && rec_f3.recompute_ops > 0);
    // Per-fmap choices genuinely differ (the point of Fig 17).
    assert_ne!(rec_f2.recompute_ops, rec_f3.recompute_ops);
    // Compounding (paper §VI-E): recomputing *both* costs more than the sum
    // of the individual recomputations — recomputing Fmap3's halo demands
    // Fmap2 inputs that are themselves no longer retained.
    assert!(
        rec_both.recompute_ops > rec_f2.recompute_ops + rec_f3.recompute_ops,
        "no compounding: both={} f2={} f3={}",
        rec_both.recompute_ops,
        rec_f2.recompute_ops,
        rec_f3.recompute_ops
    );
    // And capacity: recomputing trades buffer space for ops.
    assert!(
        rec_both.per_tensor_occupancy[fmap2.0] <= retain_both.per_tensor_occupancy[fmap2.0]
    );
}

#[test]
fn energy_breakdown_sums() {
    let fs = workloads::conv_conv(14, 8);
    let m = eval(&fs, &p2_mapping(&fs, 4));
    let b = &m.energy;
    assert!(b.dram_pj > 0.0 && b.glb_pj > 0.0 && b.compute_pj > 0.0);
    assert!((b.total_pj() - (b.dram_pj + b.glb_pj + b.rf_pj + b.compute_pj + b.noc_pj)).abs() < 1e-6);
}

#[test]
fn memory_bound_when_bandwidth_tiny() {
    let fs = workloads::conv_conv(14, 8);
    let mut arch = Arch::generic(1 << 20);
    arch.levels[0].bandwidth_words_per_cycle = 0.01;
    let mapping = p2_mapping(&fs, 4);
    let m = evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
    assert!(m.memory_cycles > m.compute_cycles);
    assert_eq!(m.latency_cycles, m.memory_cycles);
}

#[test]
fn ragged_tiles_conserve_work() {
    let fs = workloads::conv_conv(27, 8); // P2 = 25, tile 4 -> ragged
    let m = eval(&fs, &p2_mapping(&fs, 4));
    assert_eq!(m.total_ops, fs.total_ops());
    assert_eq!(m.offchip_total(), fs.algmin_offchip_elems());
}

#[test]
fn force_reference_is_bit_identical() {
    // The EvalOptions escape hatch must route through the exhaustive walk
    // and agree with the default (fast-path) evaluation exactly.
    let fs = workloads::conv_conv(28, 8);
    let arch = Arch::generic(1 << 16);
    let mapping = p2_mapping(&fs, 3); // ragged: 26 = 8·3 + 2
    let fast = evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
    let reference = evaluate(
        &fs,
        &arch,
        &mapping,
        &EvalOptions { force_reference: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(fast.total_ops, reference.total_ops);
    assert_eq!(fast.offchip_total(), reference.offchip_total());
    assert_eq!(fast.latency_cycles, reference.latency_cycles);
    assert_eq!(fast.occupancy_peak, reference.occupancy_peak);
    assert_eq!(fast.iterations, reference.iterations);
    assert_eq!(
        fast.energy.total_pj().to_bits(),
        reference.energy.total_pj().to_bits()
    );
}

#[test]
fn attention_workload_evaluates() {
    let fs = workloads::self_attention(2, 4, 64, 32);
    let last = fs.last();
    let mrank = last.rank_index("M2").unwrap();
    let m = eval(
        &fs,
        &InterLayerMapping::tiled(
            vec![Partition { dim: mrank, tile: 16 }],
            Parallelism::Sequential,
        ),
    );
    assert_eq!(m.recompute_ops, 0); // score tiles don't overlap along M
    assert_eq!(m.offchip_total(), fs.algmin_offchip_elems());
}
