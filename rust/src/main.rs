//! LoopTree CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   validate   [--design <name>] [--full]   reproduce the validation tables
//!   casestudy  <fig14|fig15|fig16|fig17|fig18> [--full]
//!   analyze    --workload <spec> --schedule <R,R,..> --tiles <n,n,..> [...]
//!   search     --workload <spec> [--algorithm exhaustive|random|anneal|genetic]
//!   experiments [--full]                    regenerate everything (EXPERIMENTS.md data)
//!   speed                                   model-vs-simulator throughput
//!
//! Workload specs: conv_conv:ROWSxCH | pdp:ROWSxCH | fc_fc:TOKENSxEMB |
//! conv3:ROWSxCH | attention:B,H,T,E

use looptree::arch::Arch;
use looptree::casestudies as cs;
use looptree::coordinator::Coordinator;
use looptree::einsum::{workloads, FusionSet};
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::{evaluate, EvalOptions};
use looptree::search;
use looptree::sim::simulate;
use looptree::util::table::fmt_count;
use looptree::validation::{self, Scale};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn run(args: &[String]) -> i32 {
    match args.first().map(|s| s.as_str()) {
        Some("validate") => cmd_validate(args),
        Some("casestudy") => cmd_casestudy(args),
        Some("analyze") => cmd_analyze(args),
        Some("search") => cmd_search(args),
        Some("experiments") => cmd_experiments(args),
        Some("speed") => cmd_speed(args),
        _ => {
            eprintln!(
                "looptree — fused-layer dataflow design-space exploration\n\n\
                 usage:\n  looptree validate [--design depfin|fused-cnn|isaac|pipelayer|flat] [--full]\n  \
                 looptree casestudy <fig14|fig15|fig16|fig17|fig18> [--full]\n  \
                 looptree analyze --workload conv_conv:28x64 --schedule P2,Q2 --tiles 4,4 [--pipeline] [--sim]\n  \
                 looptree search --workload conv_conv:28x64 [--algorithm exhaustive|random|anneal|genetic] [--objective latency|energy|edp|capacity]\n  \
                 looptree experiments [--full]\n  \
                 looptree speed"
            );
            2
        }
    }
}

fn parse_workload(spec: &str) -> Result<FusionSet, String> {
    let (kind, rest) = spec.split_once(':').ok_or("workload spec needs kind:params")?;
    let nums: Vec<i64> = rest
        .split(|c| c == 'x' || c == ',')
        .map(|s| s.parse::<i64>().map_err(|e| format!("bad number {s}: {e}")))
        .collect::<Result<_, _>>()?;
    match (kind, nums.as_slice()) {
        ("conv_conv", [r, c]) => Ok(workloads::conv_conv(*r, *c)),
        ("conv3", [r, c]) => Ok(workloads::conv_conv_conv(*r, *c)),
        ("pdp", [r, c]) => Ok(workloads::pwise_dwise_pwise(*r, *c)),
        ("fc_fc", [t, e]) => Ok(workloads::fc_fc(*t, *e)),
        ("attention", [b, h, t, e]) => Ok(workloads::self_attention(*b, *h, *t, *e)),
        _ => Err(format!("unknown workload spec: {spec}")),
    }
}

fn cmd_validate(args: &[String]) -> i32 {
    let scale = if flag(args, "--full") { Scale::Full } else { Scale::Test };
    let rows = match opt(args, "--design") {
        Some("depfin") => validation::validate_depfin(scale),
        Some("fused-cnn") => validation::validate_fused_cnn(scale),
        Some("isaac") => validation::validate_isaac(scale),
        Some("pipelayer") => validation::validate_pipelayer(scale),
        Some("flat") => validation::validate_flat(scale),
        Some(other) => {
            eprintln!("unknown design {other}");
            return 2;
        }
        None => validation::run_all(scale),
    };
    println!("{}", validation::summarize(&rows));
    let worst = rows
        .iter()
        .map(|r| r.error_pct())
        .fold(0.0f64, f64::max);
    println!("worst-case error: {worst:.2}% (paper claims <= 4%)");
    0
}

fn cmd_casestudy(args: &[String]) -> i32 {
    let fast = !flag(args, "--full");
    match args.get(1).map(|s| s.as_str()) {
        Some("fig14") => println!("{}", cs::fig14::render(&cs::fig14::run(fast))),
        Some("fig15") => println!("{}", cs::fig15::render(&cs::fig15::run(fast))),
        Some("fig16") => println!("{}", cs::fig16::render(&cs::fig16::run(fast))),
        Some("fig17") => println!("{}", cs::fig17::render(&cs::fig17::run(fast))),
        Some("fig18") => println!("{}", cs::fig18::render(&cs::fig18::run(fast))),
        _ => {
            eprintln!("usage: looptree casestudy <fig14|fig15|fig16|fig17|fig18> [--full]");
            return 2;
        }
    }
    0
}

fn cmd_analyze(args: &[String]) -> i32 {
    let Some(wl) = opt(args, "--workload") else {
        eprintln!("--workload required");
        return 2;
    };
    let fs = match parse_workload(wl) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let last = fs.last();
    let mut partitions = Vec::new();
    if let (Some(sched), Some(tiles)) = (opt(args, "--schedule"), opt(args, "--tiles")) {
        let names: Vec<&str> = sched.split(',').collect();
        let sizes: Vec<i64> = tiles.split(',').filter_map(|s| s.parse().ok()).collect();
        if names.len() != sizes.len() {
            eprintln!("--schedule and --tiles must have equal arity");
            return 2;
        }
        for (n, t) in names.iter().zip(sizes) {
            let Some(dim) = last.rank_index(n) else {
                eprintln!("unknown rank {n}; last layer has {:?}", last.rank_names);
                return 2;
            };
            partitions.push(Partition { dim, tile: t });
        }
    }
    let par = if flag(args, "--pipeline") {
        Parallelism::Pipeline
    } else {
        Parallelism::Sequential
    };
    let mapping = InterLayerMapping::tiled(partitions, par);
    let glb_kib = opt(args, "--glb-kib").and_then(|s| s.parse().ok()).unwrap_or(256);
    let arch = Arch::generic(glb_kib);
    match evaluate(&fs, &arch, &mapping, &EvalOptions::default()) {
        Ok(m) => {
            println!("workload: {}", fs.name);
            println!("schedule: {}", mapping.schedule_string(&fs));
            println!("{}", m.summary());
            println!("per-tensor occupancy:");
            for (t, occ) in fs.tensors.iter().zip(&m.per_tensor_occupancy) {
                println!("  {:10} {:>12} elems", t.name, fmt_count(*occ));
            }
            if !m.capacity_ok {
                println!("WARNING: exceeds GLB capacity ({glb_kib} KiB)");
            }
            if flag(args, "--sim") {
                match simulate(&fs, &arch, &mapping) {
                    Ok(s) => println!(
                        "simulator: latency={} offchip={}r+{}w recompute={}",
                        fmt_count(s.latency_cycles),
                        fmt_count(s.offchip_reads),
                        fmt_count(s.offchip_writes),
                        fmt_count(s.recompute_ops)
                    ),
                    Err(e) => eprintln!("simulator failed: {e}"),
                }
            }
            0
        }
        Err(e) => {
            eprintln!("evaluation failed: {e}");
            1
        }
    }
}

fn cmd_search(args: &[String]) -> i32 {
    let Some(wl) = opt(args, "--workload") else {
        eprintln!("--workload required");
        return 2;
    };
    let fs = match parse_workload(wl) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let glb_kib: i64 = opt(args, "--glb-kib").and_then(|s| s.parse().ok()).unwrap_or(256);
    let arch = Arch::generic(glb_kib);
    let objective_name = opt(args, "--objective").unwrap_or("edp");
    let objective = move |m: &looptree::model::Metrics| -> f64 {
        let infeasible = if m.capacity_ok { 1.0 } else { 1e6 };
        infeasible
            * match objective_name {
                "latency" => m.latency_cycles as f64,
                "energy" => m.energy.total_pj(),
                "capacity" => m.occupancy_peak as f64,
                _ => m.latency_cycles as f64 * m.energy.total_pj(), // edp
            }
    };
    let pool = Coordinator::new(0);
    let res = match opt(args, "--algorithm").unwrap_or("exhaustive") {
        "random" => search::random_search(&fs, &arch, 2000, 1, objective, &pool),
        "anneal" => search::annealing(&fs, &arch, 2000, 1, objective),
        "genetic" => search::genetic(&fs, &arch, 40, 25, 1, objective, &pool),
        _ => {
            let cfg = looptree::mapspace::MapSpaceConfig::default();
            search::exhaustive(&fs, &arch, &cfg, objective, &pool)
        }
    };
    match res {
        Some(r) => {
            println!(
                "evaluated {} mappings; best ({objective_name}) = {:.4e}",
                r.evaluated.len(),
                r.best.score
            );
            println!("schedule: {}", r.best.mapping.schedule_string(&fs));
            println!(
                "tiles: {:?}",
                r.best.mapping.partitions.iter().map(|p| p.tile).collect::<Vec<_>>()
            );
            println!("{}", r.best.metrics.summary());
            0
        }
        None => {
            eprintln!("search produced no feasible mapping");
            1
        }
    }
}

fn cmd_experiments(args: &[String]) -> i32 {
    let full = flag(args, "--full");
    let scale = if full { Scale::Full } else { Scale::Test };
    println!("=== Validation (Tables V-VIII, Fig 13) ===");
    println!("{}", validation::summarize(&validation::run_all(scale)));
    println!("=== Fig 14 ===\n{}", cs::fig14::render(&cs::fig14::run(!full)));
    println!("=== Fig 15 ===\n{}", cs::fig15::render(&cs::fig15::run(!full)));
    println!("=== Fig 16 ===\n{}", cs::fig16::render(&cs::fig16::run(!full)));
    println!("=== Fig 17 ===\n{}", cs::fig17::render(&cs::fig17::run(!full)));
    println!("=== Fig 18 ===\n{}", cs::fig18::render(&cs::fig18::run(!full)));
    0
}

fn cmd_speed(_args: &[String]) -> i32 {
    // The paper's analytical-vs-simulator speed comparison (§IV).
    let fs = workloads::conv_conv(20, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    let mapping = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    );
    let arch = Arch::generic(1 << 20);
    let t0 = std::time::Instant::now();
    let reps = 50;
    for _ in 0..reps {
        evaluate(&fs, &arch, &mapping, &EvalOptions::default()).unwrap();
    }
    let model_t = t0.elapsed() / reps;
    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        simulate(&fs, &arch, &mapping).unwrap();
    }
    let sim_t = t1.elapsed() / 5;
    println!(
        "model: {model_t:?}/eval   simulator: {sim_t:?}/run   speedup: {:.0}x",
        sim_t.as_secs_f64() / model_t.as_secs_f64()
    );
    0
}
