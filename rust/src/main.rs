//! LoopTree CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   validate   [--design <name>] [--full] [--json]   reproduce the validation tables
//!   casestudy  <fig14|fig15|fig16|fig17|fig18> [--full]
//!   analyze    --config <file.json> | --workload <spec> --schedule <R,R,..> --tiles <n,n,..> [...]
//!              [--explain [--json]]   per-level evaluation-path diagnostics
//!   search     --config <file.json> | --workload <spec> [--algorithm ..] [--objective ..] [--seed n]
//!   network    --config <file.json> | --network <name> [--max-seg n] [--cuts 2,4,..]
//!              [--pareto [--objectives latency,energy,..] [--max-front n]]
//!   lint       --config <file.json> [--json]  static diagnostics (LT0xx codes); exit 0/1/2
//!   serve      [--port n] [--threads n] [--cache-cap n] [--quiet]
//!              long-running HTTP server over the same JSON documents, with a
//!              cross-request segment cache (see docs/PROTOCOL.md)
//!   experiments [--full]                    regenerate everything (EXPERIMENTS.md data)
//!   speed                                   model-vs-simulator throughput
//!
//! `analyze` and `search` accept a JSON config (see `examples/configs/`) and
//! emit machine-readable results with `--json`; a `search --json` document is
//! itself a valid `--config` input that reproduces the same run.
//!
//! Workload specs: conv_conv:ROWSxCH | pdp:ROWSxCH | fc_fc:TOKENSxEMB |
//! conv3:ROWSxCH | attention:B,H,T,E

use looptree::analysis::lint_document;
use looptree::arch::Arch;
use looptree::casestudies as cs;
use looptree::coordinator::Coordinator;
use looptree::mapping::{InterLayerMapping, Parallelism, Partition};
use looptree::model::Evaluator;
use looptree::network::{self, NetworkSearchSpec};
use looptree::search::{self, Algorithm, Objective, SearchSpec};
use looptree::sim::simulate;
use looptree::spec::{parse_network, parse_workload, AnalyzeConfig, NetworkConfig, SearchConfig};
use looptree::util::json::Json;
use looptree::util::table::fmt_count;
use looptree::validation::{self, Scale};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn run(args: &[String]) -> i32 {
    match args.first().map(|s| s.as_str()) {
        Some("validate") => cmd_validate(args),
        Some("casestudy") => cmd_casestudy(args),
        Some("analyze") => cmd_analyze(args),
        Some("search") => cmd_search(args),
        Some("network") => cmd_network(args),
        Some("lint") => cmd_lint(args),
        Some("serve") => cmd_serve(args),
        Some("experiments") => cmd_experiments(args),
        Some("speed") => cmd_speed(args),
        _ => {
            eprintln!(
                "looptree — fused-layer dataflow design-space exploration\n\n\
                 usage:\n  looptree validate [--design depfin|fused-cnn|isaac|pipelayer|flat] [--full] [--json]\n  \
                 looptree casestudy <fig14|fig15|fig16|fig17|fig18> [--full]\n  \
                 looptree analyze --config cfg.json [--json] | --workload conv_conv:28x64 --schedule P2,Q2 --tiles 4,4 [--pipeline] [--sim] [--explain]\n  \
                 looptree search --config cfg.json [--json] | --workload conv_conv:28x64 [--algorithm exhaustive|random|annealing|genetic] [--objective latency|energy|edp|capacity|offchip|feasible-edp] [--seed n]\n  \
                 looptree network --config cfg.json [--json] | --network resnet18|resnet18_chain|mobilenetv2|vgg16|bert[:B,H,T,E] [--max-seg n] [--cuts 2,4,..] [--algorithm ..] [--objective ..] [--seed n] [--glb-kib n] [--pareto [--objectives latency,energy,capacity,offchip] [--max-front n]]\n  \
                 looptree lint --config cfg.json [--json]\n  \
                 looptree serve [--port 4517] [--threads 0] [--cache-cap 1024] [--quiet]\n  \
                 looptree experiments [--full]\n  \
                 looptree speed"
            );
            2
        }
    }
}

fn read_config(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

fn cmd_validate(args: &[String]) -> i32 {
    let scale = if flag(args, "--full") { Scale::Full } else { Scale::Test };
    let rows = match opt(args, "--design") {
        Some("depfin") => validation::validate_depfin(scale),
        Some("fused-cnn") => validation::validate_fused_cnn(scale),
        Some("isaac") => validation::validate_isaac(scale),
        Some("pipelayer") => validation::validate_pipelayer(scale),
        Some("flat") => validation::validate_flat(scale),
        Some(other) => {
            eprintln!("unknown design {other}");
            return 2;
        }
        None => validation::run_all(scale),
    };
    if flag(args, "--json") {
        let doc = Json::Arr(
            rows.iter()
                .map(|r| {
                    // error_pct() is infinite when the reference is zero but
                    // the model is not; JSON has no inf, so encode as null.
                    let err = r.error_pct();
                    let err_json = if err.is_finite() { Json::Num(err) } else { Json::Null };
                    let mut pairs = vec![
                        ("design".to_string(), Json::Str(r.design.to_string())),
                        ("workload".to_string(), Json::Str(r.workload.clone())),
                        ("metric".to_string(), Json::Str(r.metric.to_string())),
                        ("looptree".to_string(), Json::Num(r.looptree)),
                        ("reference".to_string(), Json::Num(r.reference)),
                        ("error_pct".to_string(), err_json),
                    ];
                    if let Some(p) = r.published {
                        pairs.push(("published".to_string(), Json::Num(p)));
                    }
                    Json::Obj(pairs.into_iter().collect())
                })
                .collect(),
        );
        println!("{}", doc.pretty());
        return 0;
    }
    println!("{}", validation::summarize(&rows));
    let worst = rows
        .iter()
        .map(|r| r.error_pct())
        .fold(0.0f64, f64::max);
    println!("worst-case error: {worst:.2}% (paper claims <= 4%)");
    0
}

fn cmd_casestudy(args: &[String]) -> i32 {
    let fast = !flag(args, "--full");
    match args.get(1).map(|s| s.as_str()) {
        Some("fig14") => println!("{}", cs::fig14::render(&cs::fig14::run(fast))),
        Some("fig15") => println!("{}", cs::fig15::render(&cs::fig15::run(fast))),
        Some("fig16") => println!("{}", cs::fig16::render(&cs::fig16::run(fast))),
        Some("fig17") => println!("{}", cs::fig17::render(&cs::fig17::run(fast))),
        Some("fig18") => println!("{}", cs::fig18::render(&cs::fig18::run(fast))),
        _ => {
            eprintln!("usage: looptree casestudy <fig14|fig15|fig16|fig17|fig18> [--full]");
            return 2;
        }
    }
    0
}

/// Build an analyze request from either `--config` or the legacy flags.
fn analyze_config(args: &[String]) -> Result<AnalyzeConfig, String> {
    if let Some(path) = opt(args, "--config") {
        return AnalyzeConfig::from_json(&read_config(path)?);
    }
    let wl = opt(args, "--workload").ok_or("--workload or --config required")?;
    let fs = parse_workload(wl)?;
    let last = fs.last();
    let mut partitions = Vec::new();
    if let (Some(sched), Some(tiles)) = (opt(args, "--schedule"), opt(args, "--tiles")) {
        let names: Vec<&str> = sched.split(',').collect();
        let sizes: Vec<i64> = tiles.split(',').filter_map(|s| s.parse().ok()).collect();
        if names.len() != sizes.len() {
            return Err("--schedule and --tiles must have equal arity".into());
        }
        for (n, t) in names.iter().zip(sizes) {
            let dim = last.rank_index(n).ok_or_else(|| {
                format!("unknown rank {n}; last layer has {:?}", last.rank_names)
            })?;
            partitions.push(Partition { dim, tile: t });
        }
    }
    let par = if flag(args, "--pipeline") {
        Parallelism::Pipeline
    } else {
        Parallelism::Sequential
    };
    let mapping = InterLayerMapping::tiled(partitions, par);
    let glb_kib = opt(args, "--glb-kib").and_then(|s| s.parse().ok()).unwrap_or(256);
    Ok(AnalyzeConfig { workload: fs, arch: Arch::generic(glb_kib), mapping })
}

fn cmd_analyze(args: &[String]) -> i32 {
    let cfg = match analyze_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ev = match Evaluator::new(&cfg.workload, &cfg.arch) {
        Ok(ev) => ev,
        Err(e) => {
            eprintln!("invalid spec: {e}");
            return 2;
        }
    };
    if flag(args, "--explain") {
        return cmd_analyze_explain(args, &cfg, &ev);
    }
    match ev.evaluate(&cfg.mapping) {
        Ok(m) => {
            if flag(args, "--json") {
                // The shared result document, plus the CLI-only --sim extra.
                let mut doc = cfg.result_doc(&m);
                if let Json::Obj(o) = &mut doc {
                    if flag(args, "--sim") {
                        match simulate(&cfg.workload, &cfg.arch, &cfg.mapping) {
                            Ok(s) => {
                                let sim = Json::Obj(
                                    [
                                        (
                                            "latency_cycles".to_string(),
                                            Json::Num(s.latency_cycles as f64),
                                        ),
                                        (
                                            "compute_cycles".to_string(),
                                            Json::Num(s.compute_cycles as f64),
                                        ),
                                        (
                                            "offchip_reads".to_string(),
                                            Json::Num(s.offchip_reads as f64),
                                        ),
                                        (
                                            "offchip_writes".to_string(),
                                            Json::Num(s.offchip_writes as f64),
                                        ),
                                        (
                                            "occupancy_peak".to_string(),
                                            Json::Num(s.occupancy_peak as f64),
                                        ),
                                        ("total_ops".to_string(), Json::Num(s.total_ops as f64)),
                                        (
                                            "recompute_ops".to_string(),
                                            Json::Num(s.recompute_ops as f64),
                                        ),
                                        ("energy_pj".to_string(), Json::Num(s.energy_pj)),
                                    ]
                                    .into_iter()
                                    .collect(),
                                );
                                o.insert("simulator".into(), sim);
                            }
                            Err(e) => {
                                o.insert("simulator_error".into(), Json::Str(e));
                            }
                        }
                    }
                }
                println!("{}", doc.pretty());
                return 0;
            }
            let fs = &cfg.workload;
            println!("workload: {}", fs.name);
            println!("schedule: {}", cfg.mapping.schedule_string(fs));
            println!("{}", m.summary());
            println!("per-tensor occupancy:");
            for (t, occ) in fs.tensors.iter().zip(&m.per_tensor_occupancy) {
                println!("  {:10} {:>12} elems", t.name, fmt_count(*occ));
            }
            if !m.capacity_ok {
                println!(
                    "WARNING: exceeds GLB capacity ({} bytes)",
                    cfg.arch.glb_capacity().unwrap_or(0)
                );
            }
            if flag(args, "--sim") {
                match simulate(fs, &cfg.arch, &cfg.mapping) {
                    Ok(s) => println!(
                        "simulator: latency={} offchip={}r+{}w recompute={}",
                        fmt_count(s.latency_cycles),
                        fmt_count(s.offchip_reads),
                        fmt_count(s.offchip_writes),
                        fmt_count(s.recompute_ops)
                    ),
                    Err(e) => eprintln!("simulator failed: {e}"),
                }
            }
            0
        }
        Err(e) => {
            eprintln!("evaluation failed: {e}");
            1
        }
    }
}

/// `looptree analyze --explain`: evaluate once and report which evaluation
/// paths fired — symbolic or region walk, per-level prover verdicts, jump
/// and walk counters — as a text table or, with `--json`, an `explain`
/// object alongside the usual metrics.
fn cmd_analyze_explain(args: &[String], cfg: &AnalyzeConfig, ev: &Evaluator) -> i32 {
    let ex = match ev.explain(&cfg.mapping) {
        Ok(ex) => ex,
        Err(e) => {
            eprintln!("evaluation failed: {e}");
            return 1;
        }
    };
    if flag(args, "--json") {
        let levels = Json::Arr(
            ex.levels
                .iter()
                .map(|l| {
                    Json::Obj(
                        [
                            ("level".to_string(), Json::Num(l.level as f64)),
                            ("dim".to_string(), Json::Str(l.dim.clone())),
                            ("tile".to_string(), Json::Num(l.tile as f64)),
                            ("children".to_string(), Json::Num(l.children as f64)),
                            ("proven".to_string(), Json::Bool(l.proven)),
                            ("reason".to_string(), Json::Str(l.reason.clone())),
                            (
                                "union_width".to_string(),
                                Json::Num(l.union_width as f64),
                            ),
                        ]
                        .into_iter()
                        .collect(),
                    )
                })
                .collect(),
        );
        let explain = Json::Obj(
            [
                ("symbolic".to_string(), Json::Bool(ex.symbolic)),
                (
                    "skip_reason".to_string(),
                    match &ex.skip_reason {
                        Some(r) => Json::Str(r.clone()),
                        None => Json::Null,
                    },
                ),
                (
                    "peak_union_width".to_string(),
                    Json::Num(ex.metrics.path.peak_union_width as f64),
                ),
                (
                    "multibox_proven_jumps".to_string(),
                    Json::Num(ex.metrics.path.multibox_proven_jumps as f64),
                ),
                (
                    "multibox_certified_jumps".to_string(),
                    Json::Num(ex.metrics.path.multibox_certified_jumps as f64),
                ),
                ("levels".to_string(), levels),
            ]
            .into_iter()
            .collect(),
        );
        let mut doc = cfg.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("metrics".into(), ex.metrics.to_json());
            o.insert("explain".into(), explain);
        }
        println!("{}", doc.pretty());
        return 0;
    }
    let fs = &cfg.workload;
    println!("workload: {}", fs.name);
    println!("schedule: {}", cfg.mapping.schedule_string(fs));
    if ex.symbolic {
        let tier = if ex.metrics.path.peak_union_width >= 2 {
            "multibox union walk"
        } else {
            "single-box walk"
        };
        println!(
            "path: symbolic (closed-form {tier} covered the whole evaluation; \
             peak union width {})",
            ex.metrics.path.peak_union_width
        );
    } else {
        println!(
            "path: region walk — {}",
            ex.skip_reason.as_deref().unwrap_or("symbolic walk skipped")
        );
    }
    let p = &ex.metrics.path;
    println!(
        "jumps: {} proven ({} multibox), {} certified ({} multibox); \
         {} of {} inter-layer iterations walked",
        p.proven_jumps,
        p.multibox_proven_jumps,
        p.certified_jumps,
        p.multibox_certified_jumps,
        p.walked_iterations,
        ex.metrics.iterations
    );
    if ex.levels.is_empty() {
        println!("(untiled mapping: no schedule levels)");
    } else {
        let mut table = looptree::util::table::Table::new(&[
            "level", "dim", "tile", "children", "proven", "width", "reason",
        ]);
        for l in &ex.levels {
            table.row(&[
                l.level.to_string(),
                l.dim.clone(),
                l.tile.to_string(),
                l.children.to_string(),
                l.proven.to_string(),
                if l.union_width == 0 { "-".into() } else { l.union_width.to_string() },
                if l.reason.is_empty() { "-".into() } else { l.reason.clone() },
            ]);
        }
        println!("{}", table.render());
    }
    println!("{}", ex.metrics.summary());
    0
}

/// Build a search request from either `--config` or the legacy flags.
fn search_config(args: &[String]) -> Result<SearchConfig, String> {
    if let Some(path) = opt(args, "--config") {
        return SearchConfig::from_json(&read_config(path)?);
    }
    let wl = opt(args, "--workload").ok_or("--workload or --config required")?;
    let fs = parse_workload(wl)?;
    let glb_kib: i64 = opt(args, "--glb-kib").and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut spec = SearchSpec::default();
    if let Some(a) = opt(args, "--algorithm") {
        spec.algorithm = Algorithm::parse(a)?;
    }
    if let Some(o) = opt(args, "--objective") {
        spec.objective = Objective::parse(o)?;
    }
    if let Some(s) = opt(args, "--seed") {
        spec.seed = s.parse().map_err(|e| format!("--seed: {e}"))?;
    }
    Ok(SearchConfig { workload: fs, arch: Arch::generic(glb_kib), search: spec })
}

fn cmd_search(args: &[String]) -> i32 {
    let cfg = match search_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ev = match Evaluator::new(&cfg.workload, &cfg.arch) {
        Ok(ev) => ev,
        Err(e) => {
            eprintln!("invalid spec: {e}");
            return 2;
        }
    };
    let pool = Coordinator::new(0);
    match search::run(&ev, &cfg.search, &pool) {
        Some(r) => {
            if flag(args, "--json") {
                let doc =
                    cfg.result_doc(&r.best, r.evaluated.len(), r.pruned, r.symbolic_evals);
                println!("{}", doc.pretty());
                return 0;
            }
            println!(
                "evaluated {} mappings ({} pruned, {} via the symbolic walk, \
                 {} refusal-memo skips); best ({}) = {:.4e}",
                r.evaluated.len(),
                r.pruned,
                r.symbolic_evals,
                r.refusal_memo_hits,
                cfg.search.objective.name(),
                r.best.score
            );
            println!("schedule: {}", r.best.mapping.schedule_string(&cfg.workload));
            println!(
                "tiles: {:?}",
                r.best.mapping.partitions.iter().map(|p| p.tile).collect::<Vec<_>>()
            );
            println!("{}", r.best.metrics.summary());
            0
        }
        None => {
            eprintln!("search produced no feasible mapping");
            1
        }
    }
}

/// Build a network-partitioning request from either `--config` or flags.
fn network_config(args: &[String]) -> Result<NetworkConfig, String> {
    let mut cfg = if let Some(path) = opt(args, "--config") {
        NetworkConfig::from_json(&read_config(path)?)?
    } else {
        let name = opt(args, "--network").ok_or("--network or --config required")?;
        NetworkConfig {
            network: parse_network(name)?,
            arch: Arch::generic(256),
            segment_search: NetworkSearchSpec::default(),
            cuts: None,
            pareto: false,
        }
    };
    // Flag overrides apply on top of either source.
    if let Some(g) = opt(args, "--glb-kib") {
        let kib: i64 = g.parse().map_err(|e| format!("--glb-kib: {e}"))?;
        cfg.arch = Arch::generic(kib);
    }
    if let Some(m) = opt(args, "--max-seg") {
        cfg.segment_search.max_segment_layers =
            m.parse().map_err(|e| format!("--max-seg: {e}"))?;
    }
    if let Some(a) = opt(args, "--algorithm") {
        cfg.segment_search.search.algorithm = Algorithm::parse(a)?;
    }
    if let Some(o) = opt(args, "--objective") {
        cfg.segment_search.search.objective = Objective::parse(o)?;
    }
    if let Some(s) = opt(args, "--seed") {
        cfg.segment_search.search.seed = s.parse().map_err(|e| format!("--seed: {e}"))?;
    }
    if let Some(c) = opt(args, "--cuts") {
        let cuts: Result<Vec<usize>, _> = c.split(',').map(|s| s.parse::<usize>()).collect();
        cfg.cuts = Some(cuts.map_err(|e| format!("--cuts: {e}"))?);
    }
    if flag(args, "--pareto") {
        cfg.pareto = true;
    }
    if let Some(o) = opt(args, "--objectives") {
        cfg.segment_search.objectives = o
            .split(',')
            .map(Objective::parse)
            .collect::<Result<_, _>>()?;
    }
    if let Some(m) = opt(args, "--max-front") {
        cfg.segment_search.max_front_per_state =
            m.parse().map_err(|e| format!("--max-front: {e}"))?;
    }
    if cfg.pareto && cfg.cuts.is_some() {
        return Err(
            "--pareto searches the front over cut sets; it cannot be combined with --cuts"
                .into(),
        );
    }
    Ok(cfg)
}

/// `looptree network --pareto`: the multi-objective front over cut sets.
fn cmd_network_pareto(args: &[String], cfg: &NetworkConfig) -> i32 {
    let pool = Coordinator::new(0);
    let r = match network::search_network_pareto(
        &cfg.network,
        &cfg.arch,
        &cfg.segment_search,
        &pool,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("network pareto search failed: {e}");
            return 1;
        }
    };
    if flag(args, "--json") {
        println!("{}", cfg.result_doc_pareto(&r).pretty());
        return 0;
    }
    let names: Vec<&str> = r.objectives.iter().map(|o| o.name()).collect();
    println!(
        "{}: {} front points over [{}]; {} candidate segments ({} statically pruned), \
         {} distinct shapes searched ({} memoized front points){}",
        cfg.network.name,
        r.points.len(),
        names.join(", "),
        r.candidate_segments,
        r.candidates_pruned,
        r.distinct_searched,
        r.segment_front_points,
        if r.max_front_per_state > 0 {
            format!("; beam cap {}", r.max_front_per_state)
        } else {
            String::new()
        }
    );
    let mut header: Vec<&str> = vec!["#"];
    header.extend(names.iter().copied());
    header.push("cuts");
    header.push("fits");
    let mut table = looptree::util::table::Table::new(&header);
    for (i, p) in r.points.iter().enumerate() {
        let mut row = vec![i.to_string()];
        row.extend(p.costs.iter().map(|c| format!("{c:.4e}")));
        row.push(format!("{:?}", p.cuts));
        row.push(p.all_fit().to_string());
        table.row(&row);
    }
    println!("{}", table.render());
    for (axis, o) in r.objectives.iter().enumerate() {
        println!(
            "best {:>12}: {:.4e}",
            o.name(),
            r.min_cost(axis).unwrap_or(f64::NAN)
        );
    }
    0
}

fn cmd_network(args: &[String]) -> i32 {
    let cfg = match network_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if cfg.pareto {
        return cmd_network_pareto(args, &cfg);
    }
    let pool = Coordinator::new(0);
    let run = match &cfg.cuts {
        Some(cuts) => {
            network::evaluate_partition(&cfg.network, &cfg.arch, &cfg.segment_search, cuts, &pool)
        }
        None => network::search_network(&cfg.network, &cfg.arch, &cfg.segment_search, &pool),
    };
    match run {
        Ok(r) => {
            if flag(args, "--json") {
                println!("{}", cfg.result_doc(&r).pretty());
                return 0;
            }
            let net = &cfg.network;
            println!(
                "{}: {} layers, {} candidate segments ({} statically pruned), {} distinct \
                 shapes searched",
                net.name,
                net.num_layers(),
                r.candidate_segments,
                r.candidates_pruned,
                r.distinct_searched
            );
            println!("cuts: {:?}", r.cuts);
            let mut table = looptree::util::table::Table::new(&[
                "segment", "layers", "schedule", "score", "latency", "offchip", "fits",
            ]);
            for s in &r.segments {
                let fs = net
                    .segment_fusion_set_nodes(&s.nodes)
                    .expect("chosen segment must be buildable");
                table.row(&[
                    s.range_label(),
                    s.span.clone(),
                    s.best.mapping.schedule_string(&fs),
                    format!("{:.3e}", s.best.score),
                    fmt_count(s.best.metrics.latency_cycles),
                    fmt_count(s.best.metrics.offchip_total()),
                    s.best.metrics.capacity_ok.to_string(),
                ]);
            }
            println!("{}", table.render());
            println!(
                "total: score {:.4e}, latency {} cyc, energy {:.1} uJ, offchip {} elems, fits: {}",
                r.total_score,
                fmt_count(r.total_latency()),
                r.total_energy_pj() / 1e6,
                fmt_count(r.total_offchip()),
                r.all_fit()
            );
            0
        }
        Err(e) => {
            eprintln!("network search failed: {e}");
            1
        }
    }
}

/// `looptree lint`: static diagnostics over a config document. Exit codes:
/// 0 clean, 1 warnings only, 2 any error (including an unreadable or
/// unparseable file).
fn cmd_lint(args: &[String]) -> i32 {
    let Some(path) = opt(args, "--config") else {
        eprintln!("usage: looptree lint --config cfg.json [--json]");
        return 2;
    };
    let doc = match read_config(path) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let report = lint_document(&doc);
    if flag(args, "--json") {
        println!("{}", report.to_json().pretty());
        return report.exit_code();
    }
    for d in &report.diagnostics {
        println!("{path}: {}", d.render());
    }
    match report.exit_code() {
        0 => println!("{path}: clean"),
        code => println!(
            "{path}: {} diagnostic(s), exit {code}",
            report.diagnostics.len()
        ),
    }
    report.exit_code()
}

/// `looptree serve`: a long-running HTTP/1.1 server over the same JSON
/// documents the CLI accepts, with a cross-request segment cache (see
/// `docs/PROTOCOL.md`). Responses embed the exact one-shot `--json`
/// documents; per-request `[serve]` log lines report the cache counters.
fn cmd_serve(args: &[String]) -> i32 {
    let port: u16 = match opt(args, "--port").map(|s| s.parse()).unwrap_or(Ok(4517)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("--port: {e}");
            return 2;
        }
    };
    let threads: usize = match opt(args, "--threads").map(|s| s.parse()).unwrap_or(Ok(0)) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("--threads: {e}");
            return 2;
        }
    };
    let cache_cap: usize =
        match opt(args, "--cache-cap").map(|s| s.parse()).unwrap_or(Ok(1024)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--cache-cap: {e}");
                return 2;
            }
        };
    let opts = looptree::serve::ServeOptions {
        threads,
        cache_cap,
        quiet: flag(args, "--quiet"),
    };
    let server = match looptree::serve::Server::bind(&format!("127.0.0.1:{port}"), opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: cannot bind 127.0.0.1:{port}: {e}");
            return 2;
        }
    };
    println!(
        "looptree serve listening on http://{} (threads={}, cache-cap={})",
        server.local_addr(),
        threads,
        cache_cap
    );
    server.run();
    0
}

fn cmd_experiments(args: &[String]) -> i32 {
    let full = flag(args, "--full");
    let scale = if full { Scale::Full } else { Scale::Test };
    println!("=== Validation (Tables V-VIII, Fig 13) ===");
    println!("{}", validation::summarize(&validation::run_all(scale)));
    println!("=== Fig 14 ===\n{}", cs::fig14::render(&cs::fig14::run(!full)));
    println!("=== Fig 15 ===\n{}", cs::fig15::render(&cs::fig15::run(!full)));
    println!("=== Fig 16 ===\n{}", cs::fig16::render(&cs::fig16::run(!full)));
    println!("=== Fig 17 ===\n{}", cs::fig17::render(&cs::fig17::run(!full)));
    println!("=== Fig 18 ===\n{}", cs::fig18::render(&cs::fig18::run(!full)));
    0
}

fn cmd_speed(_args: &[String]) -> i32 {
    // The paper's analytical-vs-simulator speed comparison (§IV).
    let fs = looptree::einsum::workloads::conv_conv(20, 8);
    let p2 = fs.last().rank_index("P2").unwrap();
    let mapping = InterLayerMapping::tiled(
        vec![Partition { dim: p2, tile: 4 }],
        Parallelism::Sequential,
    );
    let arch = Arch::generic(1 << 20);
    let ev = Evaluator::new(&fs, &arch).unwrap();
    let t0 = std::time::Instant::now();
    let reps = 50;
    for _ in 0..reps {
        ev.evaluate(&mapping).unwrap();
    }
    let model_t = t0.elapsed() / reps;
    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        simulate(&fs, &arch, &mapping).unwrap();
    }
    let sim_t = t1.elapsed() / 5;
    println!(
        "model: {model_t:?}/eval   simulator: {sim_t:?}/run   speedup: {:.0}x",
        sim_t.as_secs_f64() / model_t.as_secs_f64()
    );
    0
}
