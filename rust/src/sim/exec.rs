//! Element-driven execution of an inter-layer mapping.

use super::bitmap::Bitmap;
use crate::arch::{energy, Arch};
use crate::einsum::{EinsumSpec, FusionSet, TensorKind};
use crate::mapping::{InterLayerMapping, IntraLayerMapping, Parallelism};
use crate::model::{IterWalk, TileWindows};
use crate::poly::IBox;

/// Simulator outputs (subset of the model's metrics, measured by execution).
#[derive(Debug, Clone, Default)]
pub struct SimMetrics {
    /// Measured end-to-end latency.
    pub latency_cycles: i64,
    /// Cycles spent computing.
    pub compute_cycles: i64,
    /// Elements read from off-chip.
    pub offchip_reads: i64,
    /// Elements written off-chip.
    pub offchip_writes: i64,
    /// Peak on-chip occupancy (elements).
    pub occupancy_peak: i64,
    /// Peak occupancy per tensor (elements).
    pub per_tensor_occupancy: Vec<i64>,
    /// Off-chip traffic per tensor (elements).
    pub per_tensor_offchip: Vec<i64>,
    /// Operations executed, including recomputation.
    pub total_ops: i64,
    /// Operations re-executed due to discarded intermediates.
    pub recompute_ops: i64,
    /// Total energy (pJ).
    pub energy_pj: f64,
    /// Leaf tile windows executed.
    pub iterations: i64,
}

/// The op sub-box that produces one output element: output-projected dims
/// pinned to the element's coordinates, reduction dims full.
fn op_box_for_output(e: &EinsumSpec, coords: &[i64]) -> IBox {
    let mut b = e.domain();
    for (expr, &c) in e.output.map.exprs.iter().zip(coords) {
        let d = expr.as_identity().expect("identity output access");
        b.dims[d] = crate::poly::Interval::new(c, c + 1);
    }
    b
}

/// Execute the mapping element-by-element and measure.
pub fn simulate(
    fs: &FusionSet,
    arch: &Arch,
    mapping: &InterLayerMapping,
) -> Result<SimMetrics, String> {
    fs.validate()?;
    arch.validate()?;
    mapping.validate(fs)?;
    // The element-driven walk threads demand through the `t-1 -> t` chain
    // link below; branched (DAG) fusion sets are the analytical model's
    // territory.
    if !fs.is_chain() {
        return Err(format!(
            "simulator supports chain fusion sets only; `{}` has branching dataflow",
            fs.name
        ));
    }

    let n = fs.num_layers();
    let nt = fs.tensors.len();
    let tw = TileWindows::new(fs, mapping);
    let counts = tw.counts().to_vec();
    let k = counts.len();
    let retention: Vec<usize> = (0..nt)
        .map(|x| mapping.retention_for(crate::einsum::TensorId(x)))
        .collect();
    let intra: Vec<IntraLayerMapping> = fs
        .einsums
        .iter()
        .map(|e| IntraLayerMapping::default_for(e, arch.noc.num_pes()))
        .collect();
    let fanout: Vec<i64> = intra
        .iter()
        .map(|im| im.fanout().clamp(1, arch.compute.macs))
        .collect();

    let mut avail: Vec<Bitmap> =
        fs.tensors.iter().map(|t| Bitmap::new(&t.shape)).collect();
    // Scratch bitmaps for demand dedup per layer output tensor.
    let mut window_cache: Vec<Option<(Vec<i64>, Vec<Bitmap>)>> = vec![None; k + 1];

    let mut m = SimMetrics {
        per_tensor_occupancy: vec![0; nt],
        per_tensor_offchip: vec![0; nt],
        ..SimMetrics::default()
    };
    let mut produced: Vec<i64> = vec![0; nt];
    let mut op_total = 0i64;
    let mut glb_reads = 0i64;
    let mut glb_writes = 0i64;
    let mut noc_hop_words = 0f64;
    let mut rf_reads = 0i64;
    let mut rf_writes = 0i64;
    // Timing state: per-stage completion and a double-buffered DRAM channel.
    let mut stage_finish = vec![0i64; n];
    let mut fetch_done = 0i64;
    let dram_bw = arch.dram().bandwidth_words_per_cycle;
    let mut seq_cycles = 0i64;
    let mut prev_occ = vec![0i64; nt];
    let mut energy_pj = 0f64;

    let mut walk = IterWalk::new(&counts);
    while let Some((idx, adv)) = walk.step() {
        m.iterations += 1;
        // Retention invalidation: keep only the new window's footprint.
        // Output fmaps are exempt: their avail set tracks "already written"
        // (outputs are written off-chip exactly once; partial sums accumulate
        // on-chip under the Buffets assumption), and their occupancy is the
        // per-iteration drain tile, accounted separately below.
        for x in 0..nt {
            if fs.tensors[x].kind == TensorKind::OutputFmap {
                continue;
            }
            let j = retention[x];
            if j == 0 {
                continue;
            }
            let changed = match adv {
                None => true,
                Some(a) => a < j,
            };
            if !changed {
                continue;
            }
            let prefix = &idx[0..j];
            let refresh = match &window_cache[j] {
                Some((p, _)) if p == prefix => false,
                _ => true,
            };
            if refresh {
                window_cache[j] = Some((prefix.to_vec(), window_need_bitmaps(fs, &tw.window(prefix))));
            }
            let (_, needs) = window_cache[j].as_ref().unwrap();
            // Keep only the new window's footprint: avail &= window needs.
            avail[x].and(&needs[x]);
        }

        // Element-driven backward execution.
        let win = tw.window(&idx);
        let mut fetched_words_iter = 0i64;
        let mut tile_lat = vec![0i64; n];

        // Demand for the last layer: every output element of the tile.
        let last = &fs.einsums[n - 1];
        let out_box = last.output.map.image_box(&win);
        let mut demand: Vec<Vec<i64>> = box_coords(&out_box);
        let mut fresh_iter = vec![0i64; nt];

        for t in (0..n).rev() {
            let e = &fs.einsums[t];
            let out = e.output.tensor.0;
            // Last layer: ops run for every demanded output element (partial
            // sums accumulate when a reduction rank is partitioned), but an
            // element is *produced* (counted once) only on its first visit.
            // Upstream layers: demand is exactly the fresh intermediate
            // elements, all genuinely produced now.
            let op_elems: Vec<Vec<i64>>;
            if t == n - 1 {
                let mut fresh = 0i64;
                for c in &demand {
                    if !avail[out].get(c) {
                        avail[out].set(c);
                        fresh += 1;
                    }
                }
                produced[out] += fresh;
                fresh_iter[out] += fresh;
                op_elems = std::mem::take(&mut demand);
            } else {
                let mut fresh_elems: Vec<Vec<i64>> = Vec::new();
                for c in demand.drain(..) {
                    if !avail[out].get(&c) {
                        avail[out].set(&c);
                        fresh_elems.push(c);
                    }
                }
                produced[out] += fresh_elems.len() as i64;
                fresh_iter[out] += fresh_elems.len() as i64;
                op_elems = fresh_elems;
            }
            // Per-element op volume: the op box restricted to the iteration
            // window at the last layer, full reduction extent upstream.
            let mut ops = 0i64;
            let mut op_bbox: Option<IBox> = None;
            let mut next_demand: Vec<Vec<i64>> = Vec::new();
            let inter_input = if t > 0 {
                Some(fs.einsums[t - 1].output.tensor)
            } else {
                None
            };
            for c in &op_elems {
                let mut opb = op_box_for_output(e, c);
                if t == n - 1 {
                    opb = opb.intersect(&win);
                }
                ops += opb.volume();
                op_bbox = Some(match op_bbox {
                    None => opb.clone(),
                    Some(bb) => bb.hull(&opb),
                });
                for acc in &e.inputs {
                    let x = acc.tensor;
                    let need = acc.map.image_box(&opb);
                    if inter_input == Some(x) {
                        collect_fresh(&mut avail[x.0], &need, &mut next_demand);
                    } else {
                        let fr = avail[x.0].absorb_box(&need);
                        m.per_tensor_offchip[x.0] += fr;
                        m.offchip_reads += fr;
                        fetched_words_iter += fr;
                    }
                }
            }
            op_total += ops;
            tile_lat[t] = ops.div_ceil(fanout[t]);
            seq_cycles += tile_lat[t];
            energy_pj +=
                ops as f64 * energy::op_energy_pj(e.op_kind, arch.compute.mac_energy_pj);
            // Intra-layer action counts (shared semantics; independently
            // derived ops / bbox / produced).
            if let Some(bb) = &op_bbox {
                let produced_now = fresh_iter[out];
                let c = crate::model::tile_counts_from(e, &intra[t], arch, ops, bb, produced_now);
                glb_reads += c.glb_reads;
                glb_writes += c.glb_writes;
                noc_hop_words += c.noc_hop_words;
                rf_reads += c.rf_reads;
                rf_writes += c.rf_writes;
            }
            if t > 0 {
                // next_demand coords were *pre-set* in avail to dedupe; unset
                // them so the producer's fresh check counts them.
                for c in &next_demand {
                    unset(&mut avail[fs.einsums[t - 1].output.tensor.0], c);
                }
                demand = next_demand;
            } else {
                debug_assert!(next_demand.is_empty());
            }
        }

        // GLB fill/drain traffic for this iteration.
        glb_writes += fetched_words_iter;
        let final_out = fs.einsums[n - 1].output.tensor.0;
        glb_reads += fresh_iter[final_out];

        // Timing: double-buffered DRAM channel — this iteration's fetches
        // must complete before its compute starts (output drains are folded
        // into the total-channel-time bound below).
        fetch_done += if dram_bw.is_finite() && dram_bw > 0.0 {
            (fetched_words_iter as f64 / dram_bw).ceil() as i64
        } else {
            0
        };
        let mut prev_stage = fetch_done.max(0);
        for t in 0..n {
            let start = prev_stage.max(stage_finish[t]);
            let fin = start + tile_lat[t];
            match mapping.parallelism {
                Parallelism::Pipeline => {
                    stage_finish[t] = fin;
                    prev_stage = fin;
                }
                Parallelism::Sequential => {
                    // All stages of one iteration run back to back.
                    stage_finish[t] = fin;
                    prev_stage = fin;
                }
            }
        }
        if mapping.parallelism == Parallelism::Sequential {
            // Serialize iterations entirely.
            let fin = *stage_finish.last().unwrap();
            for s in stage_finish.iter_mut() {
                *s = fin;
            }
        }

        // Occupancy. Output fmaps occupy only their per-iteration drain tile.
        let mut total_occ = 0i64;
        for x in 0..nt {
            let occ = if fs.tensors[x].kind == TensorKind::OutputFmap {
                out_box.volume()
            } else {
                avail[x].count()
            };
            let eff = if mapping.parallelism == Parallelism::Pipeline
                && fs.tensors[x].kind == TensorKind::Intermediate
            {
                prev_occ[x] + fresh_iter[x]
            } else {
                occ
            };
            m.per_tensor_occupancy[x] = m.per_tensor_occupancy[x].max(eff);
            prev_occ[x] = occ;
            total_occ += occ;
        }
        m.occupancy_peak = m.occupancy_peak.max(total_occ);
    }

    // Off-chip writes: every element of the final output drains exactly once.
    let out_tid = fs.einsums[n - 1].output.tensor.0;
    m.offchip_writes = fs.tensors[out_tid].size();
    m.per_tensor_offchip[out_tid] = m.offchip_writes;

    m.total_ops = op_total;
    m.recompute_ops = op_total - fs.total_ops();
    m.compute_cycles = match mapping.parallelism {
        Parallelism::Sequential => seq_cycles,
        Parallelism::Pipeline => *stage_finish.iter().max().unwrap(),
    };
    // DRAM channel time for all traffic (including the final drain).
    let dram_cycles = if dram_bw.is_finite() && dram_bw > 0.0 {
        (((m.offchip_reads + m.offchip_writes) as f64) / dram_bw).ceil() as i64
    } else {
        0
    };
    m.latency_cycles = m.compute_cycles.max(dram_cycles);
    if mapping.parallelism == Parallelism::Pipeline {
        m.occupancy_peak = m.occupancy_peak.max(m.per_tensor_occupancy.iter().sum());
    }

    // Energy from measured counts — same per-action costs as the model,
    // counts derived by execution.
    let dram = arch.dram();
    let glb = arch.glb();
    energy_pj += m.offchip_reads as f64 * dram.read_energy_pj
        + m.offchip_writes as f64 * dram.write_energy_pj;
    energy_pj +=
        glb_reads as f64 * glb.read_energy_pj + glb_writes as f64 * glb.write_energy_pj;
    if let Some(rf) = arch.levels.get(2) {
        energy_pj +=
            rf_reads as f64 * rf.read_energy_pj + rf_writes as f64 * rf.write_energy_pj;
    }
    energy_pj += noc_hop_words * arch.noc.hop_energy_pj;
    m.energy_pj = energy_pj;
    let _ = produced;
    Ok(m)
}

fn window_need_bitmaps(fs: &FusionSet, win: &IBox) -> Vec<Bitmap> {
    let n = fs.num_layers();
    let mut needs: Vec<Bitmap> =
        fs.tensors.iter().map(|t| Bitmap::new(&t.shape)).collect();
    let last = &fs.einsums[n - 1];
    let mut demand: Vec<Vec<i64>> = box_coords(&last.output.map.image_box(win));
    for c in &demand {
        needs[last.output.tensor.0].set(c);
    }
    for t in (0..n).rev() {
        let e = &fs.einsums[t];
        // `demand` is already deduplicated (marked in needs[out] by the
        // consumer's collect_fresh, or explicitly for the last layer).
        let fresh: Vec<Vec<i64>> = demand.drain(..).collect();
        let inter_input = if t > 0 {
            Some(fs.einsums[t - 1].output.tensor)
        } else {
            None
        };
        let mut next: Vec<Vec<i64>> = Vec::new();
        for acc in &e.inputs {
            let is_inter = inter_input == Some(acc.tensor);
            for c in &fresh {
                let mut opb = op_box_for_output(e, c);
                if t == n - 1 {
                    opb = opb.intersect(win);
                }
                let need = acc.map.image_box(&opb);
                if is_inter {
                    collect_fresh(&mut needs[acc.tensor.0], &need, &mut next);
                } else {
                    needs[acc.tensor.0].set_box(&need);
                }
            }
        }
        if t > 0 {
            demand = next;
        }
    }
    needs
}

/// Enumerate all coordinates inside a box.
fn box_coords(b: &IBox) -> Vec<Vec<i64>> {
    if b.is_empty() {
        return vec![];
    }
    let mut out = Vec::with_capacity(b.volume() as usize);
    let mut c: Vec<i64> = b.dims.iter().map(|d| d.lo).collect();
    loop {
        out.push(c.clone());
        let mut d = b.ndim();
        loop {
            if d == 0 {
                return out;
            }
            d -= 1;
            c[d] += 1;
            if c[d] < b.dims[d].hi {
                break;
            }
            c[d] = b.dims[d].lo;
        }
    }
}

/// For every unset coordinate of `b` in `bm`: set it and push to `out`
/// (dedup via the bitmap itself).
fn collect_fresh(bm: &mut Bitmap, b: &IBox, out: &mut Vec<Vec<i64>>) {
    for c in box_coords(b) {
        if !bm.get(&c) {
            bm.set(&c);
            out.push(c);
        }
    }
}

fn unset(bm: &mut Bitmap, coords: &[i64]) {
    bm.clear_bit(coords);
}
