//! Dense per-element bitmaps over tensor coordinate spaces.
//!
//! The simulator tracks buffer contents *concretely*: one bit per tensor
//! element. This is deliberately a different representation from the model's
//! symbolic regions — the two implementations must agree on every count,
//! which is what the model-vs-sim validation (and the property tests)
//! checks.

use crate::poly::IBox;

/// A bitset over the elements of a tensor with the given shape
/// (row-major linearization).
#[derive(Debug, Clone)]
pub struct Bitmap {
    shape: Vec<i64>,
    strides: Vec<i64>,
    words: Vec<u64>,
    len: i64,
}

impl Bitmap {
    /// An all-clear bitmap over a dense tensor of `shape`.
    pub fn new(shape: &[i64]) -> Self {
        let len: i64 = shape.iter().product();
        let mut strides = vec![1i64; shape.len()];
        for d in (0..shape.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        Bitmap {
            shape: shape.to_vec(),
            strides,
            words: vec![0; ((len + 63) / 64) as usize],
            len,
        }
    }

    /// The tensor shape.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    fn offset(&self, coords: &[i64]) -> i64 {
        debug_assert_eq!(coords.len(), self.shape.len());
        coords
            .iter()
            .zip(&self.strides)
            .map(|(&c, &s)| {
                debug_assert!(c >= 0);
                c * s
            })
            .sum()
    }

    /// Whether the element at `coords` is set.
    pub fn get(&self, coords: &[i64]) -> bool {
        let o = self.offset(coords);
        self.words[(o / 64) as usize] >> (o % 64) & 1 == 1
    }

    /// Mark the element at `coords`.
    pub fn set(&mut self, coords: &[i64]) {
        let o = self.offset(coords);
        self.words[(o / 64) as usize] |= 1 << (o % 64);
    }

    /// Number of set elements.
    pub fn count(&self) -> i64 {
        self.words.iter().map(|w| w.count_ones() as i64).sum()
    }

    /// Reset all elements.
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Set every element inside `b` (clipped to the tensor bounds).
    pub fn set_box(&mut self, b: &IBox) {
        self.for_each_run(b, |words, start, len| {
            set_run(words, start, len);
        });
    }

    /// Keep only the bits inside `b`.
    pub fn retain_box(&mut self, b: &IBox) {
        let mut mask = Bitmap::new(&self.shape);
        mask.set_box(b);
        for (w, m) in self.words.iter_mut().zip(&mask.words) {
            *w &= m;
        }
    }

    /// `self |= other`.
    pub fn or(&mut self, other: &Bitmap) {
        debug_assert_eq!(self.shape, other.shape);
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w |= o;
        }
    }

    /// `self &= other`.
    pub fn and(&mut self, other: &Bitmap) {
        debug_assert_eq!(self.shape, other.shape);
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w &= o;
        }
    }

    /// Clear one bit.
    pub fn clear_bit(&mut self, coords: &[i64]) {
        let o = self.offset(coords);
        self.words[(o / 64) as usize] &= !(1 << (o % 64));
    }

    /// Count the bits of `b`'s interior that are NOT set, then set them.
    /// Returns the number of newly set bits — the "fresh" volume.
    pub fn absorb_box(&mut self, b: &IBox) -> i64 {
        let mut fresh = 0i64;
        self.for_each_run(b, |words, start, len| {
            fresh += absorb_run(words, start, len);
        });
        fresh
    }

    /// Call `f(words, start_bit, run_len)` for every contiguous row run of
    /// `b` (runs are along the innermost dimension).
    fn for_each_run(&mut self, b: &IBox, mut f: impl FnMut(&mut [u64], i64, i64)) {
        if b.is_empty() || self.shape.is_empty() {
            return;
        }
        debug_assert_eq!(b.ndim(), self.shape.len());
        // Clip to bounds.
        let mut lo = Vec::with_capacity(b.ndim());
        let mut hi = Vec::with_capacity(b.ndim());
        for (d, iv) in b.dims.iter().enumerate() {
            let l = iv.lo.max(0);
            let h = iv.hi.min(self.shape[d]);
            if h <= l {
                return;
            }
            lo.push(l);
            hi.push(h);
        }
        let nd = self.shape.len();
        let run_len = hi[nd - 1] - lo[nd - 1];
        let mut coords = lo.clone();
        loop {
            let start = self.offset(&coords);
            f(&mut self.words, start, run_len);
            // Advance all but the innermost dim.
            let mut d = nd.saturating_sub(1);
            loop {
                if d == 0 {
                    return;
                }
                d -= 1;
                coords[d] += 1;
                if coords[d] < hi[d] {
                    break;
                }
                coords[d] = lo[d];
            }
        }
    }

    /// Total element count of the shape.
    pub fn num_elems(&self) -> i64 {
        self.len
    }
}

fn set_run(words: &mut [u64], start: i64, len: i64) {
    let (mut bit, end) = (start, start + len);
    while bit < end {
        let w = (bit / 64) as usize;
        let b0 = bit % 64;
        let take = (64 - b0).min(end - bit);
        let mask = if take == 64 { !0u64 } else { ((1u64 << take) - 1) << b0 };
        words[w] |= mask;
        bit += take;
    }
}

fn absorb_run(words: &mut [u64], start: i64, len: i64) -> i64 {
    let (mut bit, end, mut fresh) = (start, start + len, 0i64);
    while bit < end {
        let w = (bit / 64) as usize;
        let b0 = bit % 64;
        let take = (64 - b0).min(end - bit);
        let mask = if take == 64 { !0u64 } else { ((1u64 << take) - 1) << b0 };
        fresh += (mask & !words[w]).count_ones() as i64;
        words[w] |= mask;
        bit += take;
    }
    fresh
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bx(bounds: &[(i64, i64)]) -> IBox {
        IBox::from_bounds(bounds)
    }

    #[test]
    fn set_and_count() {
        let mut b = Bitmap::new(&[4, 10]);
        b.set_box(&bx(&[(1, 3), (2, 9)]));
        assert_eq!(b.count(), 2 * 7);
        assert!(b.get(&[1, 2]));
        assert!(!b.get(&[0, 2]));
        assert!(!b.get(&[1, 9]));
    }

    #[test]
    fn absorb_counts_fresh_only() {
        let mut b = Bitmap::new(&[8, 8]);
        assert_eq!(b.absorb_box(&bx(&[(0, 4), (0, 4)])), 16);
        assert_eq!(b.absorb_box(&bx(&[(2, 6), (2, 6)])), 16 - 4);
        assert_eq!(b.count(), 28);
    }

    #[test]
    fn retain_keeps_window_only() {
        let mut b = Bitmap::new(&[8, 8]);
        b.set_box(&bx(&[(0, 8), (0, 8)]));
        b.retain_box(&bx(&[(2, 4), (0, 8)]));
        assert_eq!(b.count(), 16);
        assert!(b.get(&[2, 0]));
        assert!(!b.get(&[0, 0]));
    }

    #[test]
    fn clipping_out_of_bounds_boxes() {
        let mut b = Bitmap::new(&[4, 4]);
        b.set_box(&bx(&[(-2, 2), (3, 10)]));
        assert_eq!(b.count(), 2 * 1);
    }

    #[test]
    fn crossing_word_boundaries() {
        let mut b = Bitmap::new(&[3, 100]);
        b.set_box(&bx(&[(0, 3), (0, 100)]));
        assert_eq!(b.count(), 300);
        let mut c = Bitmap::new(&[300]);
        assert_eq!(c.absorb_box(&bx(&[(60, 70)])), 10);
        assert_eq!(c.absorb_box(&bx(&[(0, 300)])), 290);
    }
}
