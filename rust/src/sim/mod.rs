//! Reference tile-level simulator — the validation comparator (paper §V).
//!
//! The paper validates LoopTree against prior architectures, in one case via
//! "a simulation based on the architecture description". This module is that
//! simulator for our validation methodology: an *executable* implementation
//! of the same mapping semantics, built on a deliberately different
//! substrate — dense per-element bitmaps and element-driven dependency
//! marking instead of the model's symbolic region algebra, plus an explicit
//! double-buffered DRAM-channel timing simulation instead of the model's
//! closed-form `max(compute, memory)`.
//!
//! Counts (off-chip transfers, recompute, occupancy) must agree with the
//! model exactly; latency agrees up to pipeline fill/drain effects — the
//! validation tables report the error.

mod bitmap;
mod exec;

pub use bitmap::Bitmap;
pub use exec::{simulate, SimMetrics};
