//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust request path (python never runs at request time).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. One
//! compiled executable per model/stage variant (see python/compile/aot.py
//! for the artifact list and DESIGN.md for the interchange-format rationale).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Byte/FLOP accounting for one executable, used by the e2e example to
/// cross-check the LoopTree model's transfer predictions against what the
/// executed schedule actually moved.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Number of executions.
    pub invocations: u64,
    /// Total input elements transferred.
    pub input_elems: u64,
    /// Total output elements transferred.
    pub output_elems: u64,
}

/// A compiled artifact plus its manifest metadata.
pub struct Executable {
    /// Artifact name from the manifest.
    pub name: String,
    /// Shapes of the executable's inputs.
    pub input_shapes: Vec<Vec<i64>>,
    exe: xla::PjRtLoadedExecutable,
    /// Accumulated execution statistics.
    pub stats: ExecStats,
}

impl Executable {
    /// Execute on f32 inputs (shape-checked against the manifest). Returns
    /// the flattened f32 output.
    pub fn run_f32(&mut self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        if inputs.len() != self.input_shapes.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.input_shapes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().enumerate() {
            let want: i64 = self.input_shapes[i].iter().product();
            if *shape != self.input_shapes[i].as_slice() || data.len() as i64 != want {
                return Err(anyhow!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.name,
                    shape,
                    self.input_shapes[i]
                ));
            }
            let lit = xla::Literal::vec1(data).reshape(shape)?;
            literals.push(lit);
            self.stats.input_elems += data.len() as u64;
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        self.stats.invocations += 1;
        self.stats.output_elems += values.len() as u64;
        Ok(values)
    }
}

/// The artifact runtime: a PJRT CPU client plus the compiled executables
/// named in `artifacts/manifest.json`.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    cache: HashMap<String, usize>,
    executables: Vec<Executable>,
}

impl Runtime {
    /// Open the artifact directory (compiles lazily per executable).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
            executables: Vec::new(),
        })
    }

    /// The PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Config section of the manifest (tile sizes, shapes).
    pub fn config_i64(&self, key: &str) -> Result<i64> {
        self.manifest
            .get("config")
            .and_then(|c| c.get(key))
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow!("manifest config key {key} missing"))
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&mut self, name: &str) -> Result<&mut Executable> {
        if let Some(&i) = self.cache.get(name) {
            return Ok(&mut self.executables[i]);
        }
        let meta = self
            .manifest
            .get("artifacts")
            .and_then(|a| a.get(name))
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        let file = meta
            .get("file")
            .and_then(|f| f.as_str())
            .ok_or_else(|| anyhow!("artifact {name}: no file"))?;
        let input_shapes: Vec<Vec<i64>> = meta
            .get("inputs")
            .and_then(|i| i.as_arr())
            .ok_or_else(|| anyhow!("artifact {name}: no inputs"))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .map(|dims| dims.iter().filter_map(|d| d.as_i64()).collect())
                    .unwrap_or_default()
            })
            .collect();
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.push(Executable {
            name: name.to_string(),
            input_shapes,
            exe,
            stats: ExecStats::default(),
        });
        let idx = self.executables.len() - 1;
        self.cache.insert(name.to_string(), idx);
        Ok(&mut self.executables[idx])
    }

    /// Aggregate stats across all loaded executables.
    pub fn total_stats(&self) -> ExecStats {
        let mut s = ExecStats::default();
        for e in &self.executables {
            s.invocations += e.stats.invocations;
            s.input_elems += e.stats.input_elems;
            s.output_elems += e.stats.output_elems;
        }
        s
    }
}
