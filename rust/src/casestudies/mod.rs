//! Case studies (paper §VI, Figs 14–18): the experiments that show why the
//! extended design space matters. Each driver regenerates one figure's data
//! as printable rows; the matching `rust/benches/bench_fig1X.rs` binaries
//! print them under `cargo bench`, and `looptree casestudy figNN` runs them
//! from the CLI.
//!
//! Experimental knobs follow the paper's setup table (Table IX): the
//! independent variable is swept, everything else is searched; searches run
//! on the unbounded-GLB generic architecture because the studies measure
//! *required* capacity.

pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod fig18;

use crate::arch::Arch;
use crate::einsum::FusionSet;
use crate::mapping::InterLayerMapping;
use crate::model::{Evaluator, Metrics};

/// The case-study architecture: generic Eyeriss-class, unbounded GLB.
pub fn study_arch() -> Arch {
    Arch::generic(1 << 20).unbounded_glb()
}

/// Validate-once session on the study architecture: each figure's sweep
/// evaluates hundreds of mappings of the same fusion set, so the figures
/// create one session per fusion set and reuse it (the hot-path API).
pub fn study_session(fs: &FusionSet) -> Evaluator {
    Evaluator::new(fs, &study_arch()).unwrap_or_else(|e| panic!("{}: {e}", fs.name))
}

/// Evaluate on a session, panicking on structural errors (case-study
/// mappings are generated, so errors are bugs).
pub fn eval(ev: &Evaluator, mapping: &InterLayerMapping) -> Metrics {
    ev.evaluate(mapping)
        .unwrap_or_else(|e| panic!("{}: {e}", ev.fusion_set().name))
}

/// Tile-size choices for a rank in the studies: extent/8 and extent/4
/// (small enough to show tiling benefits, large enough to keep the
/// analytical walks fast — the paper's qualitative conclusions are
/// tile-size independent).
pub fn study_tiles(extent: i64) -> Vec<i64> {
    let mut v: Vec<i64> = [extent / 8, extent / 4]
        .into_iter()
        .filter(|&t| t >= 1)
        .collect();
    v.dedup();
    if v.is_empty() {
        v.push(1);
    }
    v
}

#[cfg(test)]
mod tests;
