//! Case-study semantic checks: the paper's five takeaways must emerge from
//! the model on fast-scale runs.

use super::*;

#[test]
fn fig14_schedule_choice_matters() {
    let bars = fig14::run(true);
    assert!(!bars.is_empty());
    // Every (fusion set, shape) group has at least two feasible schedules
    // with different capacities, and the spread is large for conv+conv.
    let conv: Vec<i64> = bars
        .iter()
        .filter(|b| b.fusion_set.starts_with("conv+conv") && b.shape == "r28,c32")
        .filter_map(|b| b.capacity)
        .collect();
    assert!(conv.len() >= 2);
    let (min, max) = (
        *conv.iter().min().unwrap(),
        *conv.iter().max().unwrap(),
    );
    assert!(
        max as f64 / min as f64 >= 2.0,
        "schedule spread too small: {min}..{max}"
    );
    let rendered = fig14::render(&bars);
    assert!(rendered.contains("spread"));
}

#[test]
fn fig14_optimal_tracks_shape() {
    // Takeaway 1: with many channels (filters large), a channel-ish
    // schedule wins; with large rows, a row schedule wins.
    let bars = fig14::run(true);
    let best_for = |shape: &str| -> String {
        bars.iter()
            .filter(|b| b.fusion_set.starts_with("conv+conv") && b.shape == shape)
            .filter(|b| b.capacity.is_some())
            .min_by_key(|b| b.capacity.unwrap())
            .map(|b| b.schedule.clone())
            .unwrap()
    };
    let row_heavy = best_for("r28,c32");
    let chan_heavy = best_for("r14,c128");
    assert_ne!(
        row_heavy, chan_heavy,
        "no single schedule should win every shape (paper takeaway 1)"
    );
    assert!(row_heavy.starts_with('P'), "row-heavy shape prefers P: {row_heavy}");
}

#[test]
fn fig15_recompute_trades_capacity() {
    let curves = fig15::run(true);
    assert!(!curves.is_empty());
    // At least one schedule exhibits a real trade-off: a point with
    // recomputation has lower capacity than the no-recompute point.
    let mut found = false;
    for c in &curves {
        let no_rec = c
            .points
            .iter()
            .filter(|p| p.recompute_frac == 0.0)
            .map(|p| p.capacity)
            .min();
        let with_rec = c
            .points
            .iter()
            .filter(|p| p.recompute_frac > 0.0)
            .map(|p| p.capacity)
            .min();
        if let (Some(nr), Some(wr)) = (no_rec, with_rec) {
            if wr < nr {
                found = true;
            }
        }
    }
    assert!(found, "no schedule showed a recompute/capacity trade-off");
}

#[test]
fn fig16_per_tensor_beats_uniform() {
    let res = fig16::run(true);
    assert!(!res.per_tensor.is_empty() && !res.uniform.is_empty());
    let best = |pts: &[fig16::Point]| pts.iter().min_by_key(|p| (p.offchip, p.capacity)).unwrap().capacity;
    let (pt, un) = (best(&res.per_tensor), best(&res.uniform));
    assert!(
        pt <= un,
        "per-tensor ({pt}) should need no more capacity than uniform ({un}) at min transfers"
    );
    // Both mapspaces reach the same minimum transfers.
    let min_t = |pts: &[fig16::Point]| pts.iter().map(|p| p.offchip).min().unwrap();
    assert_eq!(min_t(&res.per_tensor), min_t(&res.uniform));
}

#[test]
fn fig17_mixed_choices_and_compounding() {
    let curves = fig17::run(true);
    assert_eq!(curves.len(), 4);
    let min_cap = |tag: &str| -> i64 {
        curves
            .iter()
            .find(|c| c.choices == tag)
            .unwrap()
            .points
            .iter()
            .map(|&(_, c)| c)
            .min()
            .unwrap()
    };
    // Recomputing anything shrinks the minimum capacity vs retain/retain.
    assert!(min_cap("recompute/recompute") <= min_cap("retain/retain"));
    // Mixed choices genuinely differ (the reason per-fmap choices exist).
    let rr = curves.iter().find(|c| c.choices == "recompute/retain").unwrap();
    let rt = curves.iter().find(|c| c.choices == "retain/recompute").unwrap();
    assert_ne!(rr.points, rt.points);
}

#[test]
fn fig18_fused_wins_at_large_capacity_baseline_at_small() {
    let f = fig18::run(true);
    assert!(!f.fused.is_empty() && !f.baseline.is_empty());
    // Fused achieves strictly fewer transfers than the baseline can.
    let fused_min = f.fused.iter().map(|&(_, t)| t).min().unwrap();
    let base_min = f.baseline.iter().map(|&(_, t)| t).min().unwrap();
    assert!(
        fused_min < base_min,
        "fusion must save the intermediate's round trip: {fused_min} vs {base_min}"
    );
    // At small capacities the baseline achieves fewer transfers than fused
    // mappings of the same capacity (paper takeaway 5) — compare the fronts
    // at the baseline's smallest capacity point.
    let (small_cap, base_t) = *f.baseline.iter().min_by_key(|&&(c, _)| c).unwrap();
    let fused_at_small = f
        .fused
        .iter()
        .filter(|&&(c, _)| c <= small_cap)
        .map(|&(_, t)| t)
        .min();
    match fused_at_small {
        None => {} // fused cannot even fit: baseline trivially wins
        Some(ft) => assert!(
            base_t <= ft,
            "baseline should win at capacity {small_cap}: base {base_t} vs fused {ft}"
        ),
    }
}
