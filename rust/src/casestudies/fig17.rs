//! Fig 17: per-intermediate-fmap retain-recompute choices on
//! conv+conv+conv with the P3,Q3 schedule.
//!
//! Paper takeaway 4: mixed per-fmap choices beat uniform ones; recomputing
//! *later* fmaps compounds into earlier layers, so "recompute Fmap2 /
//! retain Fmap3" dominates "retain Fmap2 / recompute Fmap3".

use super::{eval, study_session, study_tiles};
use crate::einsum::{workloads, TensorId};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::{pareto_front, ParetoPoint};
use crate::util::table::Table;

/// A (choice-pair) curve: retain/recompute per fmap.
#[derive(Debug, Clone)]
pub struct Curve {
    /// e.g. "retain/recompute" for (Fmap2, Fmap3).
    pub choices: String,
    /// (normalized recompute, capacity) Pareto points.
    pub points: Vec<(f64, i64)>,
}

/// Compute the figure's curves (`fast` shrinks the workload for CI).
pub fn run(fast: bool) -> Vec<Curve> {
    let (r, c) = if fast { (24, 8) } else { (56, 32) };
    let fs = workloads::conv_conv_conv(r, c);
    let ev = study_session(&fs);
    let last = fs.last();
    let p3 = last.rank_index("P3").unwrap();
    let q3 = last.rank_index("Q3").unwrap();
    let fmap2 = TensorId(2);
    let fmap3 = TensorId(4);
    debug_assert_eq!(fs.tensor(fmap2).name, "Fmap2");
    debug_assert_eq!(fs.tensor(fmap3).name, "Fmap3");

    let mut curves = Vec::new();
    // Retention level 1 = retain the P3 band (no recompute across P3);
    // level 2 = keep only the P3,Q3 box (recompute the halo).
    for (l2, l3, tag) in [
        (1usize, 1usize, "retain/retain"),
        (2, 1, "recompute/retain"),
        (1, 2, "retain/recompute"),
        (2, 2, "recompute/recompute"),
    ] {
        let mut pts: Vec<ParetoPoint<(f64, i64)>> = Vec::new();
        for &tp in &study_tiles(last.rank_sizes[p3]) {
            for &tq in &study_tiles(last.rank_sizes[q3]) {
                let mapping = InterLayerMapping::tiled(
                    vec![
                        Partition { dim: p3, tile: tp },
                        Partition { dim: q3, tile: tq },
                    ],
                    Parallelism::Sequential,
                )
                .with_retention(fmap2, l2)
                .with_retention(fmap3, l3);
                let m = eval(&ev, &mapping);
                let cap: i64 = m.per_tensor_occupancy.iter().sum();
                pts.push(ParetoPoint {
                    x: m.recompute_fraction(),
                    y: cap as f64,
                    payload: (m.recompute_fraction(), cap),
                });
            }
        }
        curves.push(Curve {
            choices: tag.into(),
            points: pareto_front(pts).into_iter().map(|p| p.payload).collect(),
        });
    }
    curves
}

/// Render the curves as a text table.
pub fn render(curves: &[Curve]) -> String {
    let mut t = Table::new(&["Fmap2/Fmap3 choice", "recompute frac", "capacity"]);
    for c in curves {
        for &(rf, cap) in &c.points {
            t.row(&[c.choices.clone(), format!("{rf:.3}"), cap.to_string()]);
        }
    }
    t.render()
}
