//! Fig 16: per-tensor vs. uniform retention on conv+conv — off-chip
//! transfers against buffer capacity, plus the capacity breakdown at the
//! minimum-transfer point.
//!
//! Paper takeaway 3: per-tensor retention adapts each tensor's retained
//! tile to its own reuse pattern; uniform retention over-retains filters.

use super::{eval, study_session};
use crate::einsum::{workloads, FusionSet, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::{pareto_front, ParetoPoint};
use crate::model::Evaluator;
use crate::util::table::Table;

#[derive(Debug, Clone)]
/// One (capacity, off-chip) point with a per-tensor breakdown.
pub struct Point {
    /// On-chip capacity (elements).
    pub capacity: i64,
    /// Off-chip transfers (elements).
    pub offchip: i64,
    /// Per-tensor occupancy breakdown.
    pub breakdown: Vec<(String, i64)>,
}

#[derive(Debug, Clone)]
/// Fronts for per-tensor vs uniform retention.
pub struct Result14 {
    /// Front with per-tensor retention choices.
    pub per_tensor: Vec<Point>,
    /// Front with a single uniform retention level.
    pub uniform: Vec<Point>,
}

fn explore(ev: &Evaluator, uniform: bool) -> Vec<Point> {
    let fs = ev.fusion_set();
    let last = fs.last();
    let p = last.rank_index("P2").unwrap();
    let q = last.rank_index("Q2").unwrap();
    let c = last.rank_index("C2").unwrap();
    let algmin_ops = fs.total_ops();
    let mut pts: Vec<ParetoPoint<Point>> = Vec::new();

    // Schedule candidates with varied tile sizes.
    let mut parted: Vec<Vec<Partition>> = Vec::new();
    for &(d1, d2) in &[(p, q), (c, p), (p, c)] {
        for &t1 in &super::study_tiles(last.rank_sizes[d1]) {
            for &t2 in &super::study_tiles(last.rank_sizes[d2]) {
                parted.push(vec![
                    Partition { dim: d1, tile: t1 },
                    Partition { dim: d2, tile: t2 },
                ]);
            }
        }
    }
    let tensors: Vec<TensorId> = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect();

    for partitions in parted {
        let k = partitions.len();
        if uniform {
            for lvl in 0..=k {
                let mapping = InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential)
                    .with_uniform_retention(lvl);
                let m = eval(ev, &mapping);
                if m.total_ops != algmin_ops {
                    continue; // no recomputation in this study
                }
                let cap: i64 = m.per_tensor_occupancy.iter().sum();
                pts.push(ParetoPoint {
                    x: cap as f64,
                    y: m.offchip_total() as f64,
                    payload: Point {
                        capacity: cap,
                        offchip: m.offchip_total(),
                        breakdown: breakdown(fs, &m.per_tensor_occupancy),
                    },
                });
            }
        } else {
            let combos = (k + 1).pow(tensors.len() as u32);
            for combo in 0..combos {
                let mut mapping =
                    InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential);
                let mut cc = combo;
                for &t in &tensors {
                    mapping = mapping.with_retention(t, cc % (k + 1));
                    cc /= k + 1;
                }
                let m = eval(ev, &mapping);
                if m.total_ops != algmin_ops {
                    continue;
                }
                let cap: i64 = m.per_tensor_occupancy.iter().sum();
                pts.push(ParetoPoint {
                    x: cap as f64,
                    y: m.offchip_total() as f64,
                    payload: Point {
                        capacity: cap,
                        offchip: m.offchip_total(),
                        breakdown: breakdown(fs, &m.per_tensor_occupancy),
                    },
                });
            }
        }
    }
    pareto_front(pts).into_iter().map(|p| p.payload).collect()
}

fn breakdown(fs: &FusionSet, occ: &[i64]) -> Vec<(String, i64)> {
    fs.tensors
        .iter()
        .zip(occ)
        .map(|(t, &o)| (t.name.clone(), o))
        .collect()
}

/// Compute the figure's data (`fast` shrinks the workload for CI).
pub fn run(fast: bool) -> Result14 {
    let (r, c) = if fast { (28, 32) } else { (56, 64) };
    let fs = workloads::conv_conv(r, c);
    let ev = study_session(&fs);
    Result14 {
        per_tensor: explore(&ev, false),
        uniform: explore(&ev, true),
    }
}

/// Render the result as a text table.
pub fn render(res: &Result14) -> String {
    let mut t = Table::new(&["mapspace", "capacity", "offchip", "Filter1+Filter2 share"]);
    for (tag, pts) in [("per-tensor", &res.per_tensor), ("uniform", &res.uniform)] {
        for p in pts {
            let filters: i64 = p
                .breakdown
                .iter()
                .filter(|(n, _)| n.starts_with("Filter"))
                .map(|(_, v)| *v)
                .sum();
            t.row(&[
                tag.to_string(),
                p.capacity.to_string(),
                p.offchip.to_string(),
                format!("{:.0}%", 100.0 * filters as f64 / p.capacity.max(1) as f64),
            ]);
        }
    }
    let mut out = t.render();
    // The headline: capacity at the min-transfer point.
    let best = |pts: &[Point]| -> Option<(i64, i64)> {
        pts.iter()
            .min_by_key(|p| (p.offchip, p.capacity))
            .map(|p| (p.capacity, p.offchip))
    };
    if let (Some((cp, _)), Some((cu, _))) = (best(&res.per_tensor), best(&res.uniform)) {
        out.push_str(&format!(
            "\nper-tensor retention reduces capacity at min transfers: {cu} -> {cp} ({:.1}x)\n",
            cu as f64 / cp.max(1) as f64
        ));
    }
    out
}
