//! Fig 14: buffer capacity required for algorithmic-minimum off-chip
//! transfers under different partitioned-ranks/schedule choices, without
//! recomputation — across the three fusion sets and shape sweeps.
//!
//! Paper takeaway 1: the best schedule fully reuses (and therefore fully
//! retains) the *smallest* tensors; choices differ by up to 10×, and no
//! single choice wins for every fusion-set shape.

use super::{eval, study_session, study_tiles};
use crate::einsum::{workloads, FusionSet, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::model::Evaluator;
use crate::util::table::Table;

/// One bar of the figure: a schedule's minimum capacity at alg-min
/// transfers.
#[derive(Debug, Clone)]
pub struct Bar {
    /// Fusion-set label.
    pub fusion_set: String,
    /// Workload shape label.
    pub shape: String,
    /// Schedule label.
    pub schedule: String,
    /// Minimum on-chip capacity (elements) achieving alg-min transfers with
    /// zero recomputation; `None` if the schedule cannot achieve it.
    pub capacity: Option<i64>,
    /// Per-tensor capacity breakdown at the optimum.
    pub breakdown: Vec<(String, i64)>,
}

/// Candidate schedules per fusion set (rank names of the last layer): the
/// paper's compared choices.
fn candidate_schedules(fs: &FusionSet) -> Vec<Vec<String>> {
    let n = fs.num_layers();
    let last = fs.last();
    let mut cands: Vec<Vec<String>> = Vec::new();
    for names in [
        vec![format!("P{n}")],
        vec![format!("P{n}"), format!("Q{n}")],
        vec![format!("C{n}")],
        vec![format!("M{n}")],
        vec![format!("C{n}"), format!("P{n}")],
        vec![format!("E{n}")],
        vec![format!("D{n}")],
    ] {
        if names.iter().all(|r| last.rank_index(r).is_some()) {
            cands.push(names);
        }
    }
    cands
}

/// Minimum capacity at alg-min transfers for one schedule (searching tile
/// shapes and per-tensor retention; paper Table IX row B).
pub fn min_capacity_algmin(
    ev: &Evaluator,
    schedule: &[String],
) -> Option<(i64, Vec<(String, i64)>, i64)> {
    let fs = ev.fusion_set();
    let last = fs.last();
    let dims: Vec<usize> = schedule.iter().map(|r| last.rank_index(r).unwrap()).collect();
    let algmin = fs.algmin_offchip_elems();
    let mut best: Option<(i64, Vec<(String, i64)>, i64)> = None;

    // Tile-size cross product.
    let tiles_per_level: Vec<Vec<i64>> =
        dims.iter().map(|&d| study_tiles(last.rank_sizes[d])).collect();
    let mut stack = vec![0usize; dims.len()];
    let mut done = false;
    while !done {
        let partitions: Vec<Partition> = dims
            .iter()
            .zip(&stack)
            .enumerate()
            .map(|(lvl, (&dim, &ti))| Partition { dim, tile: tiles_per_level[lvl][ti] })
            .collect();
        let k = partitions.len();
        // Retention variants: for each non-output tensor, the level is the
        // shallowest that avoids refetch — found by trying deepest-first and
        // keeping the best feasible combination. Exhaustive over (k+1)^t is
        // affordable for t ≤ 4 non-output tensors and k ≤ 2.
        let tensors: Vec<TensorId> = fs
            .tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
            .map(|(i, _)| TensorId(i))
            .collect();
        let combos = (k + 1).pow(tensors.len() as u32);
        for combo in 0..combos {
            let mut mapping =
                InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential);
            let mut c = combo;
            for &t in &tensors {
                mapping = mapping.with_retention(t, c % (k + 1));
                c /= k + 1;
            }
            let m = eval(ev, &mapping);
            if m.recompute_ops != 0 || m.offchip_total() != algmin {
                continue;
            }
            let cap: i64 = m.per_tensor_occupancy.iter().sum();
            if best.as_ref().map(|(b, _, _)| cap < *b).unwrap_or(true) {
                let breakdown = fs
                    .tensors
                    .iter()
                    .zip(&m.per_tensor_occupancy)
                    .map(|(t, &o)| (t.name.clone(), o))
                    .collect();
                best = Some((cap, breakdown, algmin));
            }
        }
        // Odometer.
        let mut lvl = dims.len();
        loop {
            if lvl == 0 {
                done = true;
                break;
            }
            lvl -= 1;
            stack[lvl] += 1;
            if stack[lvl] < tiles_per_level[lvl].len() {
                break;
            }
            stack[lvl] = 0;
        }
        if dims.is_empty() {
            break;
        }
    }
    best
}

/// Run the full figure: every fusion set × shape × schedule.
pub fn run(fast: bool) -> Vec<Bar> {
    let mut bars = Vec::new();
    let conv_shapes: &[(i64, i64)] = if fast {
        &[(28, 32), (14, 128)]
    } else {
        &workloads::CONV_CONV_SHAPES
    };
    let pdp_shapes: &[(i64, i64)] = if fast {
        &[(28, 16)]
    } else {
        &workloads::PDP_SHAPES
    };
    let fc_shapes: &[(i64, i64)] = if fast {
        &[(512, 256)]
    } else {
        &workloads::FC_FC_SHAPES
    };

    let mut sets: Vec<(String, FusionSet)> = Vec::new();
    for &(r, c) in conv_shapes {
        sets.push((format!("r{r},c{c}"), workloads::conv_conv(r, c)));
    }
    for &(r, c) in pdp_shapes {
        sets.push((format!("r{r},c{c}"), workloads::pwise_dwise_pwise(r, c)));
    }
    for &(t, e) in fc_shapes {
        sets.push((format!("t{t},e{e}"), workloads::fc_fc(t, e)));
    }

    for (shape, fs) in sets {
        let ev = study_session(&fs);
        for sched in candidate_schedules(&fs) {
            let res = min_capacity_algmin(&ev, &sched);
            bars.push(Bar {
                fusion_set: fs.name.split('(').next().unwrap_or(&fs.name).to_string(),
                shape: shape.clone(),
                schedule: sched.join(","),
                capacity: res.as_ref().map(|(c, _, _)| *c),
                breakdown: res.map(|(_, b, _)| b).unwrap_or_default(),
            });
        }
    }
    bars
}

/// Render the figure as a table (the bench/CLI output).
pub fn render(bars: &[Bar]) -> String {
    let mut t = Table::new(&["fusion set", "shape", "schedule", "capacity @ algmin", "largest tensors"]);
    for b in bars {
        let mut top = b.breakdown.clone();
        top.sort_by_key(|(_, v)| -v);
        let top_str = top
            .iter()
            .take(2)
            .map(|(n, v)| format!("{n}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(&[
            b.fusion_set.clone(),
            b.shape.clone(),
            b.schedule.clone(),
            b.capacity.map(|c| c.to_string()).unwrap_or_else(|| "-".into()),
            top_str,
        ]);
    }
    // Spread per (fusion set, shape): the paper's "up to 10×" observation.
    let mut out = t.render();
    let mut groups: Vec<(String, String)> = bars
        .iter()
        .map(|b| (b.fusion_set.clone(), b.shape.clone()))
        .collect();
    groups.dedup();
    out.push('\n');
    for (fsn, shape) in groups {
        let caps: Vec<i64> = bars
            .iter()
            .filter(|b| b.fusion_set == fsn && b.shape == shape)
            .filter_map(|b| b.capacity)
            .collect();
        if let (Some(&min), Some(&max)) = (caps.iter().min(), caps.iter().max()) {
            out.push_str(&format!(
                "{fsn} {shape}: schedule choice spread = {:.1}x (min {min}, max {max})\n",
                max as f64 / min as f64
            ));
        }
    }
    out
}
