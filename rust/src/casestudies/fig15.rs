//! Fig 15: recomputation vs. required buffer capacity Pareto fronts for
//! different partitioned-ranks/schedule choices on pwise+dwise+pwise.
//!
//! Paper takeaway 2: retention-recomputation, partitioned ranks, and
//! schedule must be explored *together* — with recomputation allowed, the
//! capacity-optimal schedule changes, and the Pareto slope differs per
//! schedule (recomputing small fmap tiles buys little when filters dominate
//! the buffer).

use super::{eval, study_session, study_tiles};
use crate::einsum::{workloads, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::{pareto_front, ParetoPoint};
use crate::model::Evaluator;
use crate::util::table::Table;

/// One Pareto point: normalized recompute vs capacity, with breakdown.
#[derive(Debug, Clone)]
pub struct Point {
    /// Recompute overhead fraction.
    pub recompute_frac: f64,
    /// On-chip capacity (elements).
    pub capacity: i64,
    /// Per-tensor occupancy breakdown.
    pub breakdown: Vec<(String, i64)>,
}

#[derive(Debug, Clone)]
/// One schedule's Pareto curve.
pub struct Curve {
    /// Workload shape label.
    pub shape: String,
    /// Schedule label.
    pub schedule: String,
    /// The curve's Pareto points.
    pub points: Vec<Point>,
}

/// Pareto front of (recompute, capacity) for one schedule, alg-min
/// transfers enforced (paper Table IX row C).
pub fn pareto_for_schedule(ev: &Evaluator, schedule: &[String]) -> Vec<Point> {
    let fs = ev.fusion_set();
    let last = fs.last();
    let dims: Vec<usize> = schedule.iter().map(|r| last.rank_index(r).unwrap()).collect();
    let algmin = fs.algmin_offchip_elems();
    let mut pts: Vec<ParetoPoint<Point>> = Vec::new();

    let tiles_per_level: Vec<Vec<i64>> =
        dims.iter().map(|&d| study_tiles(last.rank_sizes[d])).collect();
    let tensors: Vec<TensorId> = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect();

    let mut stack = vec![0usize; dims.len()];
    let mut done = dims.is_empty();
    while !done {
        let partitions: Vec<Partition> = dims
            .iter()
            .zip(&stack)
            .enumerate()
            .map(|(lvl, (&dim, &ti))| Partition { dim, tile: tiles_per_level[lvl][ti] })
            .collect();
        let k = partitions.len();
        let combos = (k + 1).pow(tensors.len() as u32);
        for combo in 0..combos {
            let mut mapping =
                InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential);
            let mut c = combo;
            for &t in &tensors {
                mapping = mapping.with_retention(t, c % (k + 1));
                c /= k + 1;
            }
            let m = eval(ev, &mapping);
            if m.offchip_total() != algmin {
                continue; // the study fixes transfers at the alg. minimum
            }
            let cap: i64 = m.per_tensor_occupancy.iter().sum();
            let p = Point {
                recompute_frac: m.recompute_fraction(),
                capacity: cap,
                breakdown: fs
                    .tensors
                    .iter()
                    .zip(&m.per_tensor_occupancy)
                    .map(|(t, &o)| (t.name.clone(), o))
                    .collect(),
            };
            pts.push(ParetoPoint { x: p.recompute_frac, y: cap as f64, payload: p });
        }
        let mut lvl = dims.len();
        loop {
            if lvl == 0 {
                done = true;
                break;
            }
            lvl -= 1;
            stack[lvl] += 1;
            if stack[lvl] < tiles_per_level[lvl].len() {
                break;
            }
            stack[lvl] = 0;
        }
    }
    pareto_front(pts).into_iter().map(|p| p.payload).collect()
}

/// Run the figure: pwise+dwise+pwise shape sweep × schedule candidates.
pub fn run(fast: bool) -> Vec<Curve> {
    let shapes: &[(i64, i64)] = if fast { &[(28, 16)] } else { &workloads::PDP_SHAPES };
    let mut out = Vec::new();
    for &(r, c) in shapes {
        let fs = workloads::pwise_dwise_pwise(r, c);
        let ev = study_session(&fs);
        for sched in [
            vec!["P3".to_string()],
            vec!["P3".to_string(), "Q3".to_string()],
            vec!["P3".to_string(), "C3".to_string(), "Q3".to_string()],
            vec!["C3".to_string(), "P3".to_string(), "Q3".to_string()],
        ] {
            let points = pareto_for_schedule(&ev, &sched);
            out.push(Curve {
                shape: format!("r{r},c{c}"),
                schedule: sched.join(","),
                points,
            });
        }
    }
    out
}

/// Render the curves as a text table.
pub fn render(curves: &[Curve]) -> String {
    let mut t = Table::new(&["shape", "schedule", "recompute", "capacity", "dominant tensor"]);
    for c in curves {
        for p in &c.points {
            let dom = p
                .breakdown
                .iter()
                .max_by_key(|(_, v)| *v)
                .map(|(n, v)| format!("{n}={v}"))
                .unwrap_or_default();
            t.row(&[
                c.shape.clone(),
                c.schedule.clone(),
                format!("{:.3}", p.recompute_frac),
                p.capacity.to_string(),
                dom,
            ]);
        }
    }
    t.render()
}
