//! Fig 18: tiled fusion vs. the best of layer-by-layer / untiled fusion —
//! off-chip transfers against available on-chip capacity (no recompute).
//!
//! Paper takeaway 5: tiled fusion reaches the algorithmic transfer minimum
//! at far smaller capacity than untiled fusion, but *below* that capacity
//! the layer-by-layer baseline often wins (intra-layer reuse is more
//! abundant than inter-layer reuse).

use super::{eval, study_session};
use crate::einsum::{workloads, FusionSetBuilder, TensorId, TensorKind};
use crate::mapping::{InterLayerMapping, Parallelism, Partition};
use crate::mapspace::{pareto_front, ParetoPoint};
use crate::model::Evaluator;
use crate::util::table::Table;

#[derive(Debug, Clone)]
/// The figure's two (capacity, off-chip) fronts.
pub struct Fronts {
    /// (capacity, offchip) Pareto points for tiled fusion.
    pub fused: Vec<(i64, i64)>,
    /// Best-of(layer-by-layer, untiled fusion) baseline.
    pub baseline: Vec<(i64, i64)>,
}

/// Tiled-fusion front: P2,Q2 schedules, per-tensor retention, no recompute.
fn fused_front(ev: &Evaluator) -> Vec<(i64, i64)> {
    let fs = ev.fusion_set();
    let last = fs.last();
    let p = last.rank_index("P2").unwrap();
    let q = last.rank_index("Q2").unwrap();
    let tensors: Vec<TensorId> = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect();
    let mut pts = Vec::new();
    for &tp in &super::study_tiles(last.rank_sizes[p]) {
        for &tq in &super::study_tiles(last.rank_sizes[q]) {
            let partitions = vec![
                Partition { dim: p, tile: tp },
                Partition { dim: q, tile: tq },
            ];
            let k = partitions.len();
            let combos = (k + 1).pow(tensors.len() as u32);
            for combo in 0..combos {
                let mut mapping =
                    InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential);
                let mut c = combo;
                for &t in &tensors {
                    mapping = mapping.with_retention(t, c % (k + 1));
                    c /= k + 1;
                }
                let m = eval(ev, &mapping);
                if m.recompute_ops != 0 {
                    continue;
                }
                let cap: i64 = m.per_tensor_occupancy.iter().sum();
                pts.push(ParetoPoint {
                    x: cap as f64,
                    y: m.offchip_total() as f64,
                    payload: (cap, m.offchip_total()),
                });
            }
        }
    }
    // Untiled fusion also belongs to the fused mapspace's extreme.
    let m = eval(ev, &InterLayerMapping::untiled(Parallelism::Sequential));
    let cap: i64 = m.per_tensor_occupancy.iter().sum();
    pts.push(ParetoPoint { x: cap as f64, y: m.offchip_total() as f64, payload: (cap, m.offchip_total()) });
    pareto_front(pts).into_iter().map(|p| p.payload).collect()
}

/// Layer-by-layer baseline: each conv as its own single-layer "fusion set";
/// the intermediate crosses the chip boundary twice. Combined capacity is
/// the max across layers (buffers are reused between layers); combined
/// transfers are the sum.
fn layer_by_layer_front(rows: i64, channels: i64) -> Vec<(i64, i64)> {
    // Layer 1: input (rows+2)² -> rows²; layer 2: rows² -> (rows-2)².
    let l1 = FusionSetBuilder::new("l1", &[channels, rows + 2, rows + 2])
        .conv2d(channels, 3, 3, 1)
        .build();
    let l2 = FusionSetBuilder::new("l2", &[channels, rows, rows])
        .conv2d(channels, 3, 3, 1)
        .build();
    let f1 = single_layer_front(&study_session(&l1));
    let f2 = single_layer_front(&study_session(&l2));
    let mut pts = Vec::new();
    for &(c1, t1) in &f1 {
        for &(c2, t2) in &f2 {
            pts.push(ParetoPoint {
                x: c1.max(c2) as f64,
                y: (t1 + t2) as f64,
                payload: (c1.max(c2), t1 + t2),
            });
        }
    }
    pareto_front(pts).into_iter().map(|p| p.payload).collect()
}

fn single_layer_front(ev: &Evaluator) -> Vec<(i64, i64)> {
    let fs = ev.fusion_set();
    let last = fs.last();
    let tensors: Vec<TensorId> = fs
        .tensors
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind != TensorKind::OutputFmap)
        .map(|(i, _)| TensorId(i))
        .collect();
    let mut pts = Vec::new();
    // Intra-layer tilings: single-rank P1/C1/M1 partitioning + untiled.
    let mut schedules: Vec<Vec<Partition>> = vec![vec![]];
    for name in ["P1", "C1", "M1"] {
        if let Some(d) = last.rank_index(name) {
            for &t in &super::study_tiles(last.rank_sizes[d]) {
                schedules.push(vec![Partition { dim: d, tile: t }]);
            }
        }
    }
    for partitions in schedules {
        let k = partitions.len();
        let combos = (k + 1).pow(tensors.len() as u32);
        for combo in 0..combos {
            let mut mapping =
                InterLayerMapping::tiled(partitions.clone(), Parallelism::Sequential);
            let mut c = combo;
            for &t in &tensors {
                mapping = mapping.with_retention(t, c % (k + 1));
                c /= k + 1;
            }
            let m = eval(ev, &mapping);
            let cap: i64 = m.per_tensor_occupancy.iter().sum();
            pts.push(ParetoPoint {
                x: cap as f64,
                y: m.offchip_total() as f64,
                payload: (cap, m.offchip_total()),
            });
        }
    }
    pareto_front(pts).into_iter().map(|p| p.payload).collect()
}

/// Compute the figure's data (`fast` shrinks the workload for CI).
pub fn run(fast: bool) -> Fronts {
    let (rows, channels) = if fast { (28, 32) } else { (56, 64) };
    let fs = workloads::conv_conv(rows, channels);
    Fronts {
        fused: fused_front(&study_session(&fs)),
        baseline: layer_by_layer_front(rows, channels),
    }
}

/// Render the fronts as a text table.
pub fn render(f: &Fronts) -> String {
    let mut t = Table::new(&["dataflow", "capacity", "offchip transfers"]);
    for &(c, tr) in &f.fused {
        t.row(&["tiled fused".into(), c.to_string(), tr.to_string()]);
    }
    for &(c, tr) in &f.baseline {
        t.row(&["layer-by-layer".into(), c.to_string(), tr.to_string()]);
    }
    let mut out = t.render();
    // The crossover summary.
    let fused_min_t = f.fused.iter().map(|&(_, t)| t).min().unwrap_or(0);
    let fused_cap_at_min = f
        .fused
        .iter()
        .filter(|&&(_, t)| t == fused_min_t)
        .map(|&(c, _)| c)
        .min()
        .unwrap_or(0);
    let base_min_t = f.baseline.iter().map(|&(_, t)| t).min().unwrap_or(0);
    let base_cap_at_min = f
        .baseline
        .iter()
        .filter(|&&(_, t)| t == base_min_t)
        .map(|&(c, _)| c)
        .min()
        .unwrap_or(0);
    out.push_str(&format!(
        "\nfused reaches its min transfers ({fused_min_t}) at capacity {fused_cap_at_min}; \
         baseline min transfers ({base_min_t}) at capacity {base_cap_at_min}\n"
    ));
    out
}
