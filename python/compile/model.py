"""L2: the fusion-set compute graphs in JAX, calling the L1 kernels.

Two dataflows per fusion set:
 * `*_fused`       — inter-layer tiled, via the Pallas kernels;
 * `*_layerwise`   — the layer-by-layer baseline (paper Fig 1(b)).

Plus the *per-tile stage functions* the rust L3 coordinator drives: one
compiled executable per stage/tile-shape variant, so the coordinator can own
the inter-layer schedule (retain or recompute) at runtime, exactly as the
paper's taxonomy separates the schedule (L3 choice) from the per-tile
compute (L1/L2 artifact).
"""

import jax.numpy as jnp

from .kernels import fused_conv, fused_mlp, ref


# ---------------------------------------------------------------- conv+conv

def conv_conv_fused(x, w1, w2, tile_p=8):
    """Inter-layer P2-tiled fused conv+conv (Pallas, recompute dataflow)."""
    return fused_conv.fused_conv_conv(x, w1, w2, tile_p=tile_p)


def conv_conv_layerwise(x, w1, w2):
    """Layer-by-layer baseline: whole Fmap2 materialized."""
    return ref.conv_conv(x, w1, w2)


def conv_stage(x_block, w):
    """One conv stage on one tile: the artifact the rust coordinator drives.

    x_block: [C, rows, W] (rows = fresh tile rows + producer halo);
    w: [M, C, R, S] -> [M, rows-R+1, W-S+1].
    """
    return fused_conv._conv_tile(x_block, w)


# --------------------------------------------------------------------- fc+fc

def fc_fc_fused(x, w1, w2, tile_m=16):
    """Token-tiled fused fc+fc (Pallas)."""
    return fused_mlp.fused_fc_fc(x, w1, w2, tile_m=tile_m)


def fc_fc_layerwise(x, w1, w2):
    return ref.fc_fc(x, w1, w2)


def fc_stage(x_tile, w):
    """One fc stage on one token tile: x [Tm, D] @ w [D, E]."""
    return jnp.dot(x_tile, w, preferred_element_type=jnp.float32).astype(x_tile.dtype)


# ------------------------------------------------------------------- params

def init_conv_conv(rows, channels, key_scale=0.02):
    """Deterministic pseudo-random parameters (no RNG dependency at build
    time keeps artifacts reproducible byte-for-byte)."""
    import numpy as np

    rng = np.random.default_rng(20240916)  # the paper's DOI date
    h = rows + 4  # two 3x3 halos
    x = rng.standard_normal((channels, h, h), dtype=np.float32) * 1.0
    w1 = rng.standard_normal((channels, channels, 3, 3), dtype=np.float32) * key_scale
    w2 = rng.standard_normal((channels, channels, 3, 3), dtype=np.float32) * key_scale
    return jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)


def init_fc_fc(tokens, d1, e1, e2, key_scale=0.02):
    import numpy as np

    rng = np.random.default_rng(20240916)
    x = rng.standard_normal((tokens, d1), dtype=np.float32)
    w1 = rng.standard_normal((d1, e1), dtype=np.float32) * key_scale
    w2 = rng.standard_normal((e1, e2), dtype=np.float32) * key_scale
    return jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
