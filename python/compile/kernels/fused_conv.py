"""L1 Pallas kernel: fused conv+conv with inter-layer P-tiling.

This kernel *executes* the dataflow the LoopTree model reasons about: the
grid iterates over P2 tiles of the last layer's output; each grid step
computes the producer (conv1) tile — including the halo rows, i.e. the
paper's RECOMPUTE retention-recomputation choice, since Pallas grid steps
are independent — and immediately consumes it with conv2. Only a tile of the
intermediate fmap (Fmap2) ever exists, in VMEM scratch.

TPU mapping notes (DESIGN.md §Hardware-Adaptation):
 * the haloed dynamic-slice of the input expresses the HBM↔VMEM overlap
   schedule the paper expresses with inter-layer tiling;
 * the VMEM footprint of one grid step — `C·(Tp+halo+?)·W` input rows plus
   `M1·(Tp+halo2)·(W-2)` intermediate rows — is exactly the model's
   predicted occupancy for the `P2` schedule with innermost retention
   (recompute);
 * `interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
   custom-calls; numerics are identical to a TPU lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_tile(x, w):
    """Valid conv on a tile via shifted-slice accumulation.

    x: [C, H, W]; w: [M, C, R, S] -> [M, H-R+1, W-S+1]. Written as R·S
    channel-contracting einsums so it lowers to MXU-friendly matmuls instead
    of a window gather (the TPU analogue of the paper's MAC-array mapping).
    """
    m, _, r, s = w.shape
    h_out = x.shape[1] - r + 1
    w_out = x.shape[2] - s + 1
    acc = jnp.zeros((m, h_out, w_out), dtype=jnp.float32)
    for dr in range(r):
        for ds in range(s):
            patch = x[:, dr : dr + h_out, ds : ds + w_out]
            acc = acc + jnp.einsum(
                "chw,mc->mhw",
                patch,
                w[:, :, dr, ds],
                preferred_element_type=jnp.float32,
            )
    return acc.astype(x.dtype)


def _fused_kernel(x_ref, w1_ref, w2_ref, o_ref, *, tile_p, halo):
    """One grid step: slice the haloed input rows, conv1, then conv2."""
    i = pl.program_id(0)
    # Haloed input block: rows [i*tile_p, i*tile_p + tile_p + halo).
    x = x_ref[:, pl.ds(i * tile_p, tile_p + halo), :]
    fmap2_tile = _conv_tile(x, w1_ref[...])  # recomputed halo included
    o_ref[...] = _conv_tile(fmap2_tile, w2_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_p",))
def fused_conv_conv(x, w1, w2, tile_p=8):
    """Fused conv+conv, P2-tiled: x [C,H,W], w1 [M1,C,R,S], w2 [M2,M1,R,S].

    `tile_p` is the inter-layer tile along the output-row rank (P2). The
    output height must be divisible by `tile_p` (ragged tiles are exercised
    on the rust side, which drives per-tile executables directly).
    """
    c, h, wdt = x.shape
    m1, _, r1, s1 = w1.shape
    m2, _, r2, s2 = w2.shape
    halo = (r1 - 1) + (r2 - 1)
    p_out = h - halo
    q_out = wdt - (s1 - 1) - (s2 - 1)
    assert p_out % tile_p == 0, f"P2={p_out} not divisible by tile {tile_p}"
    grid = (p_out // tile_p,)

    kernel = functools.partial(_fused_kernel, tile_p=tile_p, halo=halo)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Full input resident; the kernel takes haloed slices (Pallas
            # block indexing cannot express overlapping blocks directly).
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m2, tile_p, q_out), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((m2, p_out, q_out), x.dtype),
        interpret=True,
    )(x, w1, w2)


def vmem_footprint_words(c, w, m1, tile_p, halo1, halo_total):
    """Estimated VMEM words for one grid step (DESIGN.md §Perf): the haloed
    input block plus the intermediate tile — the model's occupancy
    prediction for the P2 schedule with innermost retention."""
    in_rows = tile_p + halo_total
    fmap2_rows = tile_p + halo1
    return c * in_rows * w + m1 * fmap2_rows * (w - 2)
