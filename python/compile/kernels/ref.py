"""Pure-jnp oracles for the fused-layer kernels.

These are the correctness references (the L1 Pallas kernels and the L2 fused
models are checked against them with `assert_allclose` in python/tests/).
Everything here is straight-line jax.numpy — no Pallas, no tiling.
"""

import jax.numpy as jnp
from jax import lax


def conv2d(x, w):
    """Valid 2D convolution (cross-correlation, the DNN convention).

    x: [C, H, W] input fmap; w: [M, C, R, S] filters -> [M, H-R+1, W-S+1].
    """
    out = lax.conv_general_dilated(
        x[None],  # [1, C, H, W]
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv_conv(x, w1, w2):
    """The paper's conv+conv fusion set (Table X row 1), layer by layer."""
    return conv2d(conv2d(x, w1), w2)


def conv_conv_intermediate(x, w1):
    """The intermediate fmap (Fmap2) — for halo/retention checks."""
    return conv2d(x, w1)


def fc_fc(x, w1, w2):
    """The paper's fc+fc fusion set (Table X row 3): x [M, D1] -> [M, E2]."""
    return (x @ w1) @ w2


def pwise_dwise_pwise(x, w1, wd, w2):
    """MobileNetV2 block (Table X row 2): pwise -> 3x3 dwise -> pwise.

    x: [C1, H, W]; w1: [M1, C1]; wd: [M1, 3, 3]; w2: [C3out, M1].
    """
    h = jnp.einsum("chw,mc->mhw", x, w1)
    # Depthwise 3x3, valid: per-channel convolution.
    d = lax.conv_general_dilated(
        h[None],
        wd[:, None, :, :],  # [M1, 1, 3, 3]
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=h.shape[0],
    )[0]
    return jnp.einsum("mhw,cm->chw", d, w2)


def attention(q, k, v):
    """Fused self-attention reference: scores -> softmax -> attend.

    q, k, v: [B, H, T, E] -> [B, H, T, E].
    """
    s = jnp.einsum("bhme,bhne->bhmn", q, k) / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhmn,bhne->bhme", p, v)
