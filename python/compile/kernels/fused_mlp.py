"""L1 Pallas kernel: fused fc+fc with token (M) tiling.

The transformer feed-forward fusion set (paper Table X row 3). Token tiles
never overlap (`m` appears bare in every access), so there is no
retention-recomputation choice (paper §VI-C) — each grid step computes one
token tile end to end, with the intermediate activations living only in
registers/VMEM. This is the degenerate-but-important case of the paper's
taxonomy, and the kernel demonstrates it executably.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One token tile through both layers: (x @ W1) @ W2."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32).astype(x.dtype)
    o_ref[...] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32).astype(
        x.dtype
    )


@functools.partial(jax.jit, static_argnames=("tile_m",))
def fused_fc_fc(x, w1, w2, tile_m=16):
    """Fused fc+fc, token-tiled: x [M, D1], w1 [D1, E1], w2 [E1, E2].

    `tile_m` is the inter-layer tile along the token rank. M must be
    divisible by `tile_m`.
    """
    m, d1 = x.shape
    _, e1 = w1.shape
    _, e2 = w2.shape
    assert m % tile_m == 0, f"M={m} not divisible by tile {tile_m}"
    grid = (m // tile_m,)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d1), lambda i: (i, 0)),
            pl.BlockSpec((d1, e1), lambda i: (0, 0)),
            pl.BlockSpec((e1, e2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, e2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, e2), x.dtype),
        interpret=True,
    )(x, w1, w2)
