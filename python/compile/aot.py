"""AOT lowering: jax → HLO **text** artifacts for the rust PJRT runtime.

HLO text (NOT `lowered.compiler_ir("hlo").serialize()`): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (all f32; shapes in artifacts/manifest.json):
 * conv_conv_fused.hlo.txt   — whole fused model, Pallas P2-tiled (L1+L2)
 * conv_conv_ref.hlo.txt     — whole layer-by-layer model (oracle)
 * conv_stage1_first.hlo.txt — conv1 on the first (haloed) tile
 * conv_stage1_steady.hlo.txt— conv1 on a steady fresh tile
 * conv_stage2.hlo.txt       — conv2 on one intermediate tile
 * mlp_fused.hlo.txt / mlp_ref.hlo.txt / mlp_stage{1,2}.hlo.txt

The stage executables let the rust coordinator own the inter-layer schedule
(retain vs recompute) while PJRT runs per-tile compute — python never on the
request path. `make artifacts` is incremental: this script is a no-op when
artifacts are newer than python/compile/**.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---- the fixed e2e configuration (examples/e2e_fused_pipeline.rs) ----
ROWS = 32          # P2 = output rows of conv2
CH = 16            # C1 = M1 = M2
TILE_P = 8         # inter-layer tile along P2
TOKENS, D1, E1, E2 = 64, 64, 128, 64
TILE_M = 16

HALO1 = 2          # conv1 output halo consumed by conv2
HALO_T = 4         # total input halo (two 3x3 layers)


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_all(outdir: str) -> dict:
    h = ROWS + HALO_T
    x = f32(CH, h, h)
    w1 = f32(CH, CH, 3, 3)
    w2 = f32(CH, CH, 3, 3)

    artifacts = {}

    def emit(name, fn, *specs, meta=None):
        text = to_hlo_text(fn, *specs)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            **(meta or {}),
        }
        print(f"  {name}: {len(text)} chars, inputs {[s.shape for s in specs]}")

    # Whole-model variants.
    emit(
        "conv_conv_fused",
        functools.partial(model.conv_conv_fused, tile_p=TILE_P),
        x, w1, w2,
        meta={"tile_p": TILE_P},
    )
    emit("conv_conv_ref", model.conv_conv_layerwise, x, w1, w2)

    # Per-tile stage executables for the rust-driven pipeline (retain
    # dataflow): the first tile produces tile_p + HALO1 intermediate rows;
    # steady tiles produce tile_p fresh rows.
    first_in_rows = TILE_P + HALO1 + 2   # fresh fmap2 rows + conv1 halo
    steady_in_rows = TILE_P + 2
    emit(
        "conv_stage1_first",
        model.conv_stage,
        f32(CH, first_in_rows, h), w1,
        meta={"fresh_rows": TILE_P + HALO1},
    )
    emit(
        "conv_stage1_steady",
        model.conv_stage,
        f32(CH, steady_in_rows, h), w1,
        meta={"fresh_rows": TILE_P},
    )
    emit(
        "conv_stage2",
        model.conv_stage,
        f32(CH, TILE_P + HALO1, h - 2), w2,
        meta={"out_rows": TILE_P},
    )

    # fc+fc variants.
    xm = f32(TOKENS, D1)
    wm1 = f32(D1, E1)
    wm2 = f32(E1, E2)
    emit(
        "mlp_fused",
        functools.partial(model.fc_fc_fused, tile_m=TILE_M),
        xm, wm1, wm2,
        meta={"tile_m": TILE_M},
    )
    emit("mlp_ref", model.fc_fc_layerwise, xm, wm1, wm2)
    emit("mlp_stage1", model.fc_stage, f32(TILE_M, D1), wm1)
    emit("mlp_stage2", model.fc_stage, f32(TILE_M, E1), wm2)

    manifest = {
        "config": {
            "rows": ROWS, "channels": CH, "tile_p": TILE_P,
            "halo1": HALO1, "halo_total": HALO_T,
            "tokens": TOKENS, "d1": D1, "e1": E1, "e2": E2, "tile_m": TILE_M,
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    print(f"lowering artifacts to {outdir}")
    build_all(outdir)
    print("done")


if __name__ == "__main__":
    main()
