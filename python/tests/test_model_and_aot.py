"""L2 model + AOT path tests: fused vs layerwise equivalence, stage-tile
composition (the schedule the rust coordinator drives), and HLO text
artifact generation."""

import json
import os

import numpy as np
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_fused_equals_layerwise_conv():
    x, w1, w2 = model.init_conv_conv(rows=16, channels=4)
    fused = model.conv_conv_fused(x, w1, w2, tile_p=4)
    layerwise = model.conv_conv_layerwise(x, w1, w2)
    assert_allclose(np.asarray(fused), np.asarray(layerwise), rtol=2e-4, atol=2e-4)


def test_fused_equals_layerwise_mlp():
    x, w1, w2 = model.init_fc_fc(tokens=32, d1=16, e1=24, e2=8)
    fused = model.fc_fc_fused(x, w1, w2, tile_m=8)
    layerwise = model.fc_fc_layerwise(x, w1, w2)
    assert_allclose(np.asarray(fused), np.asarray(layerwise), rtol=2e-4, atol=2e-4)


def test_stage_composition_retain_dataflow():
    """Drive the per-tile stage functions exactly as the rust coordinator
    does (retain dataflow: first tile produces tile+halo intermediate rows,
    steady tiles produce fresh rows only) and check against the oracle."""
    rows, ch, tile_p, halo1 = 16, 3, 4, 2
    x, w1, w2 = model.init_conv_conv(rows=rows, channels=ch)
    want = ref.conv_conv(x, w1, w2)

    h = x.shape[1]
    fmap2_rows = []  # retained intermediate band (list of row arrays)
    out_tiles = []
    produced = 0  # fmap2 rows produced so far
    for i in range(rows // tile_p):
        if i == 0:
            fresh = tile_p + halo1
            x_block = x[:, 0 : fresh + 2, :]
        else:
            fresh = tile_p
            x_block = x[:, produced : produced + fresh + 2, :]
        f2 = model.conv_stage(x_block, w1)  # [ch, fresh, h-2]
        assert f2.shape[1] == fresh
        fmap2_rows.append(np.asarray(f2))
        produced += fresh
        band = np.concatenate(fmap2_rows, axis=1)[:, -(tile_p + halo1) :, :]
        out_tiles.append(np.asarray(model.conv_stage(jnp.asarray(band), w2)))
    got = np.concatenate(out_tiles, axis=1)
    assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)
    assert produced == rows + halo1  # fmap2 fully produced, exactly once


def test_aot_emits_parseable_hlo(tmp_path):
    outdir = str(tmp_path)
    manifest = aot.build_all(outdir)
    assert "conv_conv_fused" in manifest["artifacts"]
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(outdir, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        # HLO text module header — what HloModuleProto::from_text_file needs.
        assert text.lstrip().startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, name
    with open(os.path.join(outdir, "manifest.json")) as f:
        m = json.load(f)
    assert m["config"]["tile_p"] == aot.TILE_P


def test_aot_config_consistency():
    # Shapes in the manifest must compose: stage2 consumes stage1's output.
    assert aot.ROWS % aot.TILE_P == 0
    assert aot.TOKENS % aot.TILE_M == 0
