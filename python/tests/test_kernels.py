"""L1 kernel correctness: Pallas fused kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and tile sizes; assert_allclose against ref.py is
the core correctness signal for the compile path (the same functions are
AOT-lowered into the artifacts the rust runtime executes).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import fused_conv, fused_mlp, ref


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ----------------------------------------------------------- fused conv+conv

@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    tile_p=st.sampled_from([2, 4]),
    ch=st.integers(min_value=1, max_value=6),
    width=st.integers(min_value=9, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_conv_conv_matches_ref(tiles, tile_p, ch, width, seed):
    rng = np.random.default_rng(seed)
    p_out = tiles * tile_p
    h = p_out + 4
    x = rand(rng, ch, h, width)
    w1 = rand(rng, ch, ch, 3, 3, scale=0.1)
    w2 = rand(rng, ch, ch, 3, 3, scale=0.1)
    got = fused_conv.fused_conv_conv(x, w1, w2, tile_p=tile_p)
    want = ref.conv_conv(x, w1, w2)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_fused_conv_single_tile_degenerates_to_layerwise():
    rng = np.random.default_rng(0)
    x = rand(rng, 4, 12, 12)
    w1 = rand(rng, 4, 4, 3, 3, scale=0.1)
    w2 = rand(rng, 4, 4, 3, 3, scale=0.1)
    got = fused_conv.fused_conv_conv(x, w1, w2, tile_p=8)  # one tile
    want = ref.conv_conv(x, w1, w2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_fused_conv_rejects_indivisible_tiles():
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 11, 11)  # P2 = 7, not divisible by 4
    w = rand(rng, 2, 2, 3, 3)
    with pytest.raises(AssertionError):
        fused_conv.fused_conv_conv(x, w, w, tile_p=4)


def test_conv_tile_helper_matches_lax():
    rng = np.random.default_rng(1)
    x = rand(rng, 3, 10, 9)
    w = rand(rng, 5, 3, 3, 3, scale=0.1)
    got = fused_conv._conv_tile(x, w)
    want = ref.conv2d(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_conv_tile_5x5_kernel():
    rng = np.random.default_rng(2)
    x = rand(rng, 2, 12, 12)
    w = rand(rng, 3, 2, 5, 5, scale=0.1)
    got = fused_conv._conv_tile(x, w)
    want = ref.conv2d(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- fused fc+fc

@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    tile_m=st.sampled_from([4, 8]),
    d1=st.integers(min_value=2, max_value=32),
    e1=st.integers(min_value=2, max_value=32),
    e2=st.integers(min_value=2, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_fc_fc_matches_ref(tiles, tile_m, d1, e1, e2, seed):
    rng = np.random.default_rng(seed)
    m = tiles * tile_m
    x = rand(rng, m, d1)
    w1 = rand(rng, d1, e1, scale=0.1)
    w2 = rand(rng, e1, e2, scale=0.1)
    got = fused_mlp.fused_fc_fc(x, w1, w2, tile_m=tile_m)
    want = ref.fc_fc(x, w1, w2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ----------------------------------------------------- reference self-checks

def test_ref_pwise_dwise_pwise_shapes():
    rng = np.random.default_rng(3)
    x = rand(rng, 4, 10, 10)
    w1 = rand(rng, 24, 4, scale=0.1)
    wd = rand(rng, 24, 3, 3, scale=0.1)
    w2 = rand(rng, 4, 24, scale=0.1)
    out = ref.pwise_dwise_pwise(x, w1, wd, w2)
    assert out.shape == (4, 8, 8)


def test_ref_attention_is_softmax_weighted():
    rng = np.random.default_rng(4)
    q = rand(rng, 1, 2, 6, 4)
    k = rand(rng, 1, 2, 6, 4)
    v = rand(rng, 1, 2, 6, 4)
    out = ref.attention(q, k, v)
    assert out.shape == (1, 2, 6, 4)
    # Attention outputs are convex combinations of values along tokens.
    vmin = np.asarray(v).min(axis=2, keepdims=True)
    vmax = np.asarray(v).max(axis=2, keepdims=True)
    o = np.asarray(out)
    assert (o >= vmin - 1e-4).all() and (o <= vmax + 1e-4).all()
